//! Native SwitchAll decoder block and model-level forward passes:
//! embedding, pre-LN block stack (MoE attention + dense or sigma-MoE
//! MLP), final norm, and the LM / classification heads.
//!
//! Mirrors `layers.py::block_apply` and `model.py::_encode` with a
//! fresh (zero) Transformer-XL cache — exactly the state the PJRT
//! `score` / `next_logits` entry points use — so the two backends are
//! semantically interchangeable on the inference paths.

use crate::config::{ModelConfig, Positional, Task};
use crate::kernels::scratch;
use crate::model::attention::{
    dense_attention, moa_attention, switchhead_attention, AttnCtx, LayerAux,
};
use crate::model::params::{AttnP, BlockP, MlpP, NativeModel};
use crate::model::tensor::{layer_norm, matmul, moe_matmul, route, MacCounter, Router};

/// Per-layer analysis aux collected across the stack.
#[derive(Default)]
pub struct EncodeAux {
    pub layers: Vec<LayerAux>,
}

/// Feedforward layer (dense or sigma-MoE) over `[n, d]` rows — shared
/// with the incremental decoder in `model::decode`.
pub(crate) fn mlp_apply(cfg: &ModelConfig, p: &MlpP, x: &[f32], macs: &mut MacCounter) -> Vec<f32> {
    let d = cfg.d_model;
    let n = x.len() / d;
    match p {
        MlpP::Dense { w1, w2 } => {
            let f = cfg.d_ff;
            let mut h = matmul(x, w1, n, d, f);
            for v in h.iter_mut() {
                *v = v.max(0.0); // relu
            }
            macs.mlp += (2 * n * d * f) as f64;
            let out = matmul(&h, w2, n, f, d);
            scratch::put(h);
            out
        }
        MlpP::SigmaMoe { w1, w2, w_sel } => {
            // sigma-MoE MLP (Csordas et al. 2023) — SwitchAll's FF layer.
            let (e, de, k) = (cfg.mlp_n_experts, cfg.mlp_d_expert, cfg.mlp_k);
            let (idx, gate, _) = route(x, w_sel, d, e, k, Router::Sigmoid, false, macs);
            let ones = vec![1.0f32; n];
            let mut y = scratch::take(n * d);
            for j in 0..k {
                let idx_j: Vec<usize> = (0..n).map(|i| idx[i * k + j]).collect();
                let gate_j: Vec<f32> = (0..n).map(|i| gate[i * k + j]).collect();
                let mut h = moe_matmul(x, w1, d, de, &idx_j, &ones, 1);
                for v in h.iter_mut() {
                    *v = v.max(0.0);
                }
                let o = moe_matmul(&h, w2, de, d, &idx_j, &gate_j, 1);
                scratch::put(h);
                macs.mlp += (n * (d * de + de + de * d + d)) as f64;
                for (yv, ov) in y.iter_mut().zip(&o) {
                    *yv += ov;
                }
                scratch::put(o);
            }
            y
        }
    }
}

/// Quantized [`mlp_apply`]: weights stream from the int8 bank
/// (`QuantMlp`) while routing (`w_sel`, taken from the f32 params `p`)
/// and every accumulation stay f32 — routing adds no quantization
/// error of its own and only the matmul weight loads dequantize.
/// MAC tallies match [`mlp_apply`] exactly.
pub(crate) fn mlp_apply_q(
    cfg: &ModelConfig,
    p: &MlpP,
    qm: &crate::model::params::QuantMlp,
    x: &[f32],
    macs: &mut MacCounter,
) -> Vec<f32> {
    use crate::model::params::QuantMlp;
    use crate::model::tensor::{matmul_q, moe_matmul_q};
    let d = cfg.d_model;
    let n = x.len() / d;
    match (p, qm) {
        (MlpP::Dense { .. }, QuantMlp::Dense { w1, w2 }) => {
            let f = cfg.d_ff;
            let mut h = matmul_q(x, w1, n, d, f);
            for v in h.iter_mut() {
                *v = v.max(0.0); // relu
            }
            macs.mlp += (2 * n * d * f) as f64;
            let out = matmul_q(&h, w2, n, f, d);
            scratch::put(h);
            out
        }
        (MlpP::SigmaMoe { w_sel, .. }, QuantMlp::SigmaMoe { w1, w2 }) => {
            let (e, de, k) = (cfg.mlp_n_experts, cfg.mlp_d_expert, cfg.mlp_k);
            let (idx, gate, _) = route(x, w_sel, d, e, k, Router::Sigmoid, false, macs);
            let ones = vec![1.0f32; n];
            let mut y = scratch::take(n * d);
            for j in 0..k {
                let idx_j: Vec<usize> = (0..n).map(|i| idx[i * k + j]).collect();
                let gate_j: Vec<f32> = (0..n).map(|i| gate[i * k + j]).collect();
                let mut h = moe_matmul_q(x, w1, d, de, &idx_j, &ones, 1);
                for v in h.iter_mut() {
                    *v = v.max(0.0);
                }
                let o = moe_matmul_q(&h, w2, de, d, &idx_j, &gate_j, 1);
                scratch::put(h);
                macs.mlp += (n * (d * de + de + de * d + d)) as f64;
                for (yv, ov) in y.iter_mut().zip(&o) {
                    *yv += ov;
                }
                scratch::put(o);
            }
            y
        }
        _ => unreachable!("quant mlp variant mismatch"),
    }
}

/// One pre-LN block: `x += attn(LN1(x)); x += mlp(LN2(x))`.
#[allow(clippy::too_many_arguments)]
fn block_apply(
    cfg: &ModelConfig,
    bp: &BlockP,
    x: &mut Vec<f32>,
    b: usize,
    t: usize,
    pad_mask: Option<&[bool]>,
    macs: &mut MacCounter,
    collect: Option<&mut LayerAux>,
) {
    let d = cfg.d_model;
    let x_ln = layer_norm(x, &bp.ln1.g, &bp.ln1.b, d);

    // Source side: fresh (zero) XL cache chunk ++ current chunk. The
    // cache holds raw previous block inputs in the XL convention; at
    // zero state that is a zero prefix of length seq_len.
    let (src, tk) = if cfg.pos == Positional::Xl {
        let tc = cfg.seq_len;
        let mut src = scratch::take(b * (tc + t) * d);
        for bi in 0..b {
            let dst = (bi * (tc + t) + tc) * d;
            let from = bi * t * d;
            src[dst..dst + t * d].copy_from_slice(&x_ln[from..from + t * d]);
        }
        (src, tc + t)
    } else {
        let mut src = scratch::take(x_ln.len());
        src.copy_from_slice(&x_ln);
        (src, t)
    };

    let ctx = AttnCtx { b, t, tk, pad_mask };
    let a = match &bp.attn {
        AttnP::SwitchHead(p) => switchhead_attention(cfg, p, &x_ln, &src, &ctx, macs, collect),
        AttnP::Dense(p) => dense_attention(cfg, p, &x_ln, &src, &ctx, macs, collect),
        AttnP::Moa(p) => moa_attention(cfg, p, &x_ln, &src, &ctx, macs, collect),
    };
    scratch::put(src);
    scratch::put(x_ln);
    for (xv, av) in x.iter_mut().zip(&a) {
        *xv += av;
    }
    scratch::put(a);

    let x_ln2 = layer_norm(x, &bp.ln2.g, &bp.ln2.b, d);
    let m = mlp_apply(cfg, &bp.mlp, &x_ln2, macs);
    scratch::put(x_ln2);
    for (xv, mv) in x.iter_mut().zip(&m) {
        *xv += mv;
    }
    scratch::put(m);
}

/// Run the block stack over `tokens` `[b, t]`. Returns the final-norm
/// hidden states `[b, t, d]`.
pub fn encode(
    model: &NativeModel,
    tokens: &[i32],
    b: usize,
    t: usize,
    pad_mask: Option<&[bool]>,
    macs: &mut MacCounter,
    mut collect: Option<&mut EncodeAux>,
) -> Vec<f32> {
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let scale = (d as f64).sqrt() as f32;
    let mut x = scratch::take(b * t * d);
    for (i, &tok) in tokens.iter().enumerate() {
        let row = &model.embed[(tok as usize) * d..(tok as usize + 1) * d];
        let out = &mut x[i * d..(i + 1) * d];
        for j in 0..d {
            out[j] = row[j] * scale;
        }
    }
    for bp in &model.layers {
        let layer_aux = collect.as_deref_mut().map(|aux| {
            aux.layers.push(LayerAux::default());
            aux.layers.last_mut().unwrap()
        });
        block_apply(cfg, bp, &mut x, b, t, pad_mask, macs, layer_aux);
    }
    let h = layer_norm(&x, &model.ln_f.g, &model.ln_f.b, d);
    scratch::put(x);
    h
}

/// Per-position next-token log-probabilities for a `[b, t+1]` window.
/// Returns `[b * t]` row-major — the native twin of the PJRT `score`
/// entry (fresh XL cache each call).
pub fn score(model: &NativeModel, tokens: &[i32], b: usize, macs: &mut MacCounter) -> Vec<f32> {
    let cfg = &model.cfg;
    let t = cfg.seq_len;
    let t1 = t + 1;
    let n_out = NativeModel::n_out(cfg);
    let mut inp = Vec::with_capacity(b * t);
    for bi in 0..b {
        inp.extend_from_slice(&tokens[bi * t1..bi * t1 + t]);
    }
    let h = encode(model, &inp, b, t, None, macs, None);
    let logits = matmul(&h, &model.head, b * t, cfg.d_model, n_out);
    scratch::put(h);
    let mut out = Vec::with_capacity(b * t);
    for bi in 0..b {
        for i in 0..t {
            let tgt = tokens[bi * t1 + i + 1] as usize;
            let row = &logits[(bi * t + i) * n_out..(bi * t + i + 1) * n_out];
            out.push(row[tgt] - crate::model::tensor::logsumexp(row));
        }
    }
    scratch::put(logits);
    out
}

/// Logits for the token following a `[b, t]` window; returns `[b * V]`
/// (the native twin of the PJRT `next_logits` generation entry).
pub fn next_logits(
    model: &NativeModel,
    tokens: &[i32],
    b: usize,
    macs: &mut MacCounter,
) -> Vec<f32> {
    let cfg = &model.cfg;
    let t = cfg.seq_len;
    let n_out = NativeModel::n_out(cfg);
    let h = encode(model, tokens, b, t, None, macs, None);
    let d = cfg.d_model;
    // Select the last position of each row, then project.
    let mut last = scratch::take(b * d);
    for bi in 0..b {
        let from = (bi * t + t - 1) * d;
        last[bi * d..(bi + 1) * d].copy_from_slice(&h[from..from + d]);
    }
    scratch::put(h);
    let logits = matmul(&last, &model.head, b, d, n_out);
    scratch::put(last);
    logits
}

/// ListOps classification logits `[b, n_classes]` from position 0 with
/// a padding key-mask (pad id 0, as in `model.py::listops_loss`).
pub fn class_logits(
    model: &NativeModel,
    tokens: &[i32],
    b: usize,
    macs: &mut MacCounter,
) -> Vec<f32> {
    let cfg = &model.cfg;
    debug_assert_eq!(cfg.task, Task::ListOps);
    let t = cfg.seq_len;
    let n_out = NativeModel::n_out(cfg);
    let pad_mask: Vec<bool> = tokens.iter().map(|&tok| tok != 0).collect();
    let h = encode(model, tokens, b, t, Some(&pad_mask), macs, None);
    let d = cfg.d_model;
    let mut first = scratch::take(b * d);
    for bi in 0..b {
        let from = bi * t * d;
        first[bi * d..(bi + 1) * d].copy_from_slice(&h[from..from + d]);
    }
    scratch::put(h);
    let logits = matmul(&first, &model.head, b, d, n_out);
    scratch::put(first);
    logits
}
