//! Minimal f32 host-tensor primitives for the native backend.
//!
//! All tensors are flat row-major `Vec<f32>` with dimensions passed
//! explicitly; no external linear-algebra crates (offline registry).
//! Numeric twin: `python/tools/native_ref.py` — keep operation order in
//! lock-step so the checked-in golden vectors stay valid.
//!
//! The matmul entry points delegate to [`crate::kernels`] — blocked,
//! `PALLAS_THREADS`-parallel, expert-grouped — which preserve the
//! scalar per-element accumulation order bit for bit, so the twin and
//! the golden vectors are untouched by the execution strategy.

use crate::kernels::{self, scratch};
use crate::util::rng::Pcg;

pub const NEG_INF: f32 = -1e9;

/// Multiply-accumulate accounting for the native forward pass, by the
/// categories of the paper's Eq. 11-15 (see `macs::attention_cost`).
/// `router` is tracked separately because Eq. 13 does not charge the
/// expert-selection matmuls (they are O(D*E) per token, negligible at
/// paper scale).
#[derive(Debug, Default, Clone)]
pub struct MacCounter {
    /// Dense projections (Q/K/V/O without expert structure).
    pub proj_dense: f64,
    /// MoE projections, counted as k * (matmul + gate multiply) per token.
    pub proj_moe: f64,
    /// Attention core: QK^T logits + attention-weighted value sum.
    pub attn_core: f64,
    /// Expert-selection (router) matmuls — NOT part of Eq. 13.
    pub router: f64,
    /// Positional machinery (XL relative-position projection + logits).
    pub pos: f64,
    /// Feedforward layer (dense or sigma-MoE) — outside Eq. 11-15.
    pub mlp: f64,
    /// Serving-layer bookkeeping OUTSIDE the model forward: sampling
    /// scans, speculative accept walks, queue/admission arithmetic.
    /// Tallied in scalar ops (not true MACs) by `serve::Scheduler` so
    /// `bench-serve` can split model cost from scheduler overhead;
    /// never touched by model code, and excluded from
    /// [`attention_total`](MacCounter::attention_total).
    pub scheduler_overhead: f64,
}

impl MacCounter {
    /// The attention MACs Eq. 11/13 accounts for (projections + core +
    /// positional; excludes the router, the MLP and scheduler
    /// overhead).
    pub fn attention_total(&self) -> f64 {
        self.proj_dense + self.proj_moe + self.attn_core + self.pos
    }

    /// Every tallied op (attention + router + MLP + scheduler
    /// overhead) — the whole-forward cost the decode-vs-recompute
    /// comparison uses (model sessions never tally overhead, so for
    /// them this is still pure model MACs).
    pub fn total(&self) -> f64 {
        self.proj_dense
            + self.proj_moe
            + self.attn_core
            + self.router
            + self.pos
            + self.mlp
            + self.scheduler_overhead
    }

    /// Add `other * num / den` field-wise — the fused batched decode's
    /// per-session share of its per-token-uniform work (`num` = the
    /// session's rows, `den` = the fused batch width). Multiplying
    /// before dividing keeps the integral tallies exact whenever the
    /// true share is an integer.
    pub fn add_scaled(&mut self, other: &MacCounter, num: f64, den: f64) {
        self.proj_dense += other.proj_dense * num / den;
        self.proj_moe += other.proj_moe * num / den;
        self.attn_core += other.attn_core * num / den;
        self.router += other.router * num / den;
        self.pos += other.pos * num / den;
        self.mlp += other.mlp * num / den;
        self.scheduler_overhead += other.scheduler_overhead * num / den;
    }
}

/// `[n, d] @ [d, m] -> [n, m]` (blocked + parallel; bit-identical to
/// `kernels::reference::matmul_ref`). The returned buffer comes from
/// the scratch arena — hot-path callers hand it back with
/// `scratch::put` when done.
pub fn matmul(x: &[f32], w: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    let mut out = scratch::take(n * m);
    kernels::matmul_into(&mut out, x, w, n, d, m);
    out
}

/// MoE projection (paper Eq. 9-10): per token i, sum over the selected
/// experts j of `gate[i,j] * (x_i @ experts[idx[i,j]])`.
/// `x` is `[n, rows]`; each expert matrix is `[rows, cols]`;
/// `idx`/`gate` are `[n, k]` flattened. Dispatch is expert-grouped and
/// parallel (`kernels::moe`), bit-identical to the scalar reference.
pub fn moe_matmul(
    x: &[f32],
    experts: &[Vec<f32>],
    rows: usize,
    cols: usize,
    idx: &[usize],
    gate: &[f32],
    k: usize,
) -> Vec<f32> {
    let n = x.len() / rows;
    let mut out = scratch::take(n * cols);
    kernels::moe_matmul_into(&mut out, x, experts, rows, cols, idx, gate, k);
    out
}

/// Quantized [`matmul`]: `w` stored as per-row-scaled i8
/// ([`crate::quant::QuantMat`]), dequantized on load with f32
/// accumulation (`kernels::matmul_q_into`). Scratch-arena output.
pub fn matmul_q(x: &[f32], w: &crate::quant::QuantMat, n: usize, d: usize, m: usize) -> Vec<f32> {
    let mut out = scratch::take(n * m);
    kernels::matmul_q_into(&mut out, x, w, n, d, m);
    out
}

/// Quantized [`moe_matmul`]: each expert stored as per-row-scaled i8.
/// Same expert-grouped dispatch, f32 accumulation throughout.
pub fn moe_matmul_q(
    x: &[f32],
    experts: &[crate::quant::QuantMat],
    rows: usize,
    cols: usize,
    idx: &[usize],
    gate: &[f32],
    k: usize,
) -> Vec<f32> {
    let n = x.len() / rows;
    let mut out = scratch::take(n * cols);
    kernels::moe_matmul_q_into(&mut out, x, experts, rows, cols, idx, gate, k);
    out
}

/// Row-wise layer norm over the last dimension `d` (eps = 1e-5,
/// biased variance — matches `layers.py::layer_norm`). The output
/// buffer comes from the scratch arena.
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let n = x.len() / d;
    let mut out = scratch::take(x.len());
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let mut mu = 0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0f32;
        for &v in row {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let or = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            or[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// In-place row softmax over rows of width `w` (max-subtracted).
pub fn softmax_rows(x: &mut [f32], w: usize) {
    for row in x.chunks_mut(w) {
        let mut m = f32::NEG_INFINITY;
        for &v in row.iter() {
            if v > m {
                m = v;
            }
        }
        let mut s = 0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// `log(sum(exp(row)))`, max-subtracted.
pub fn logsumexp(row: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &v in row {
        if v > m {
            m = v;
        }
    }
    let mut s = 0f32;
    for &v in row {
        s += (v - m).exp();
    }
    m + s.ln()
}

/// Iterative-argmax top-k over `scores` (first maximum wins ties) —
/// mirrors `layers.py::small_top_k`. Returns (indices, values).
pub fn top_k(scores: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; k];
    let mut val = vec![0f32; k];
    top_k_into(scores, &mut idx, &mut val);
    (idx, val)
}

/// Allocation-free [`top_k`]: selects `idx_out.len()` experts by an
/// in-place scan that skips already-chosen indices (k is small, so the
/// O(k) membership check beats the reference's full `to_vec` copy +
/// masking). Selection and tie-breaking are identical to the masked
/// scan for the finite scores a router produces.
pub fn top_k_into(scores: &[f32], idx_out: &mut [usize], val_out: &mut [f32]) {
    let k = idx_out.len();
    debug_assert!(k <= scores.len());
    debug_assert_eq!(val_out.len(), k);
    for j in 0..k {
        let chosen = &idx_out[..j];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in scores.iter().enumerate() {
            if v > bv && !chosen.contains(&i) {
                bv = v;
                best = i;
            }
        }
        idx_out[j] = best;
        val_out[j] = scores[best];
    }
}

/// Routing activation (paper §2.2 / §3.6 design choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Router {
    /// sigma-MoE non-competitive selection (the paper's choice, Eq. 7-8).
    Sigmoid,
    /// MoA-style competitive selection with renormalized top-k gates.
    Softmax,
}

impl Router {
    pub fn parse(s: &str) -> Router {
        if s == "softmax" {
            Router::Softmax
        } else {
            Router::Sigmoid
        }
    }
}

/// Route `x [n, d]` through selector `w_sel [d, e]`: returns
/// (idx `[n*k]`, gate `[n*k]`, scores `[n*e]`). The score tensor is
/// only materialized for the analysis path — pass `want_scores =
/// false` on the hot path and the buffer is recycled into the scratch
/// arena instead of returned.
#[allow(clippy::too_many_arguments)]
pub fn route(
    x: &[f32],
    w_sel: &[f32],
    d: usize,
    e: usize,
    k: usize,
    router: Router,
    want_scores: bool,
    macs: &mut MacCounter,
) -> (Vec<usize>, Vec<f32>, Option<Vec<f32>>) {
    let n = x.len() / d;
    let mut scores = matmul(x, w_sel, n, d, e);
    macs.router += (n * d * e) as f64;
    match router {
        Router::Sigmoid => {
            for v in scores.iter_mut() {
                *v = sigmoid(*v);
            }
        }
        Router::Softmax => {
            softmax_rows(&mut scores, e);
        }
    }
    let mut idx = vec![0usize; n * k];
    let mut gate = vec![0f32; n * k];
    for i in 0..n {
        let (oi, og) = (&mut idx[i * k..(i + 1) * k], &mut gate[i * k..(i + 1) * k]);
        top_k_into(&scores[i * e..(i + 1) * e], oi, og);
        if router == Router::Softmax {
            let s: f32 = og.iter().sum();
            for v in og.iter_mut() {
                *v /= s + 1e-9;
            }
        }
    }
    if want_scores {
        (idx, gate, Some(scores))
    } else {
        scratch::put(scores);
        (idx, gate, None)
    }
}

/// Classic sinusoidal embedding: `[count, d]` with `[sin | cos]` halves
/// (mirrors `layers.py::sinusoidal`; `d` must be even).
pub fn sinusoidal(count: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(count * d);
    for p in 0..count {
        out.extend(sinusoidal_row(p, d));
    }
    out
}

/// One row of [`sinusoidal`] (position `p`), bit-identical to the
/// corresponding row of the full table — the incremental decoder grows
/// its distance table row by row with this.
pub fn sinusoidal_row(p: usize, d: usize) -> Vec<f32> {
    let half = d / 2;
    let lg = (10000f64).ln() / half as f64;
    let mut out = vec![0f32; d];
    for j in 0..half {
        let ang = p as f64 * (-(j as f64) * lg).exp();
        out[j] = ang.sin() as f32;
        out[half + j] = ang.cos() as f32;
    }
    out
}

/// RoPE rotation in place: `x` is `[b, t, dh]`, row `ti` sits at
/// absolute position `pos0 + ti` (mirrors `layers.py::rope_rotate`).
pub fn rope_rotate(x: &mut [f32], b: usize, t: usize, dh: usize, pos0: usize) {
    let half = dh / 2;
    let lg = (10000f64).ln() / half as f64;
    for bi in 0..b {
        for ti in 0..t {
            let base = (bi * t + ti) * dh;
            let pos = (pos0 + ti) as f64;
            for j in 0..half {
                let ang = pos * (-(j as f64) * lg).exp();
                let (s, c) = (ang.sin() as f32, ang.cos() as f32);
                let x1 = x[base + j];
                let x2 = x[base + half + j];
                x[base + j] = x1 * c - x2 * s;
                x[base + half + j] = x1 * s + x2 * c;
            }
        }
    }
}

/// Initialization draw: normal / sqrt(fan_in), in f64 then cast — the
/// exact sequence the numpy twin replays to produce golden weights.
pub fn draw_init(rng: &mut Pcg, n: usize, fan_in: usize) -> Vec<f32> {
    let root = (fan_in as f64).sqrt();
    (0..n).map(|_| (rng.normal() / root) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        // 2x2 identity leaves rows unchanged.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&x, &id, 2, 2, 2), x);
    }

    #[test]
    fn matmul_known_values() {
        // [1,2;3,4] @ [5,6;7,8] = [19,22;43,50]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn moe_single_expert_unit_gate_is_dense() {
        let x = vec![1.0, -1.0, 0.5, 2.0];
        let w = vec![0.5, 1.0, -1.0, 0.25, 2.0, 0.0];
        let dense = matmul(&x, &w, 2, 2, 3);
        let moe = moe_matmul(&x, &[w.clone()], 2, 3, &[0, 0], &[1.0, 1.0], 1);
        assert_eq!(dense, moe);
    }

    #[test]
    fn moe_gates_scale_linearly() {
        let x = vec![1.0, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let half = moe_matmul(&x, &[w.clone()], 1, 2, &[0], &[0.5], 1);
        assert_eq!(half, vec![0.5, 1.0]);
    }

    #[test]
    fn softmax_rows_are_stochastic() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&v| v > 0.0));
        }
        // Monotone in the logits.
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, &g, &b, 4);
        let mu: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn top_k_selects_distinct_descending() {
        let scores = vec![0.1, 0.9, 0.5, 0.9, 0.2];
        let (idx, val) = top_k(&scores, 3);
        assert_eq!(idx, vec![1, 3, 2], "first max wins ties");
        assert_eq!(val, vec![0.9, 0.9, 0.5]);
    }

    #[test]
    fn logsumexp_matches_naive() {
        let row = vec![0.5, -1.0, 2.0];
        let naive = row.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp(&row) - naive).abs() < 1e-6);
    }

    #[test]
    fn sinusoidal_first_position_is_sin0_cos0() {
        let s = sinusoidal(3, 4);
        assert_eq!(&s[..4], &[0.0, 0.0, 1.0, 1.0], "pos 0: sin=0, cos=1");
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let orig = vec![1.0, 2.0, 3.0, 4.0];
        let mut x = orig.clone();
        rope_rotate(&mut x, 1, 1, 4, 0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_rotate(&mut x, 1, 1, 4, 17);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn router_invariants() {
        let mut rng = Pcg::new(5, 5);
        let x: Vec<f32> = (0..6 * 8).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut macs = MacCounter::default();
        let (idx, gate, scores) = route(&x, &w, 8, 4, 2, Router::Sigmoid, true, &mut macs);
        let scores = scores.expect("want_scores = true returns the score tensor");
        assert_eq!(idx.len(), 12);
        assert_eq!(scores.len(), 24);
        assert!(gate.iter().all(|&g| g > 0.0 && g < 1.0), "sigmoid gate range");
        assert!(scores.iter().all(|&s| s > 0.0 && s < 1.0));
        assert!(macs.router > 0.0);
        // Softmax router: per-token gates renormalize to ~1; the hot
        // path (want_scores = false) skips the score tensor entirely.
        let (_, gate, scores) = route(&x, &w, 8, 4, 2, Router::Softmax, false, &mut macs);
        assert!(scores.is_none(), "hot path must not materialize scores");
        for pair in gate.chunks(2) {
            let s: f32 = pair.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }
}
