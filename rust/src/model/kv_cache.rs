//! Paged expert-sparse KV cache: one shared block pool, per-session
//! page tables.
//!
//! # Why paging
//!
//! Until PR 5 every [`NativeSession`](super::decode::NativeSession)
//! preallocated `ctx_len` K/V columns per (layer, stream) as a ring
//! buffer — full-window memory the moment a session opened, even for a
//! three-token request. That gave SwitchHead's serving-side memory win
//! (gate-combined K/V of only the selected experts, paper Sec. 3) back
//! at scale: the scheduler could admit by slot count only, and N
//! mostly-short sessions paid N full rings. This module replaces the
//! rings with fixed-size **pages** of K/V columns drawn from a shared
//! [`KvPool`], so a session holds exactly the pages its live attention
//! window touches and thousands of short sessions share one pool — the
//! Switch Transformers turn-sparsity-into-capacity argument applied to
//! the KV cache.
//!
//! # Structure
//!
//! * [`KvPool`] — the shared block pool: two flat element stores (K
//!   and V, `max_pages * page_cols * d_head` elements each,
//!   materialized lazily), a LIFO free list of recycled page ids, and
//!   the reservation counter capacity-aware admission runs on. Cheap
//!   to clone (an `Arc` handle); all mutation is behind one mutex. The
//!   element width is set by the pool's [`Precision`]: f32 stores
//!   4-byte floats, int8 stores 1-byte codes plus one f32 scale per
//!   K/V *column* (quantized on push, f32-accumulated on read — see
//!   [`crate::quant`]).
//! * [`Kv`] — one attention stream group (one layer × one attention
//!   matrix) of one session: per row, a page table mapping logical
//!   page index `pos / page_cols` to a pool page id. Pushes append at
//!   strictly increasing positions; pages whose last position falls
//!   out of the `cap` (= `ctx_len`) attention window are freed back to
//!   the pool *before* the new position's page is allocated, so the
//!   ring/XL window semantics are preserved with bounded pages held.
//!
//! # Invariants
//!
//! * **Bit identity.** Paging changes WHERE a K/V column lives, never
//!   its value or any reduction order: [`Kv::push`] stores exactly the
//!   floats the old ring stored, and reads resolve through
//!   [`Kv::locate`] / [`Kv::for_window`] (same offsets, enumerated in
//!   ascending position order) to the same column bytes. The decode/serve
//!   equivalence suites (`rust/tests/decode.rs`, `rust/tests/serve.rs`)
//!   therefore pin paged decode to the full-window forward unchanged.
//!   An int8 pool keeps the determinism half of this contract: a
//!   pushed column's codes and scale are a pure function of its f32
//!   input, so chunked vs monolithic prefill (and speculative rollback
//!   re-pushes) still produce byte-identical stores — only the f32 ≡
//!   full-forward half is relaxed, to the quantization tolerance band.
//! * **Position-denominated capacity.** Pages hold `page_cols` K/V
//!   *positions* regardless of element width — [`stream_pages`],
//!   [`stream_pages_spec`] and every reservation/admission count is
//!   pure position arithmetic, so an int8 pool holds exactly the same
//!   positions per page (pinned by
//!   `int8_pages_hold_same_positions_per_page`) and the `pool_demand`
//!   reservation invariant is precision-invariant. Only the *bytes*
//!   behind a page shrink ([`PoolStats::bytes_per_page`]).
//! * **Page lifetime.** A page is owned by exactly one stream from
//!   allocation to the free that retires it (window slide, or
//!   [`Kv`]'s `Drop`, which returns every held page). The free list
//!   never holds a page that a live table still maps. Freed pages are
//!   not zeroed: a stream only ever reads positions it wrote, and
//!   within a stream positions are written consecutively from 0.
//! * **Reservation soundness.** Admission reserves a session's
//!   worst-case concurrent page demand ([`stream_pages`] per stream)
//!   up front and [`KvPool::try_reserve`] refuses past `max_pages`, so
//!   `sum(reservations) <= max_pages` always holds and an in-decode
//!   allocation cannot fail for any session that stays within its
//!   declared position budget. Exceeding the budget is a caller bug
//!   and panics with the pool state (the scheduler's retire logic
//!   makes it unreachable in serving).
//! * **Locking.** The pool mutex is held only inside `push`, the
//!   stats/reservation accessors, and for the duration of a borrowed
//!   [`KvRead`] view; nothing ever locks it re-entrantly (attention
//!   reads go through raw slices captured from the view, so worker
//!   threads never touch the mutex).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::Precision;
use crate::quant::quantize_row_into;
use crate::util::error::{bail, Result};

/// Worst-case pages a single stream can hold at once when writing
/// `positions` consecutive positions (from 0) under an attention
/// window of `cap` positions, with pages of `page_cols` columns.
///
/// While the stream is still growing (`positions <= cap`) pages are
/// never freed, so the bound is the aligned span `ceil(positions /
/// page_cols)`. Once the window slides, free-before-alloc keeps at
/// most `ceil((cap - 1) / page_cols) + 1` pages live (the `+1` is the
/// boundary page that still holds the window's oldest column). The
/// bound is the smaller of the two, and is what admission reserves.
pub fn stream_pages(page_cols: usize, cap: usize, positions: usize) -> usize {
    debug_assert!(page_cols > 0 && cap > 0);
    let grow = (positions.max(1) - 1) / page_cols + 1;
    let windowed = (cap - 1) / page_cols + 2 - usize::from((cap - 1) % page_cols == 0);
    grow.min(windowed)
}

/// [`stream_pages`] for a stream whose eviction trails the window by
/// `evict_lag` positions ([`Kv::set_evict_lag`] — the speculative
/// decoding mode, where the last ≤ `evict_lag` pushed positions must
/// stay rollback-safe). The lag widens the live span by at most
/// `evict_lag` positions, which costs at most
/// `ceil(evict_lag / page_cols) + 1` extra pages over the eager bound
/// (`ceil(a/pc) + ceil(b/pc) >= ceil((a+b)/pc)`, plus one page of
/// boundary slop); rollback re-pushes never raise the maximum position
/// reached, so the grow-phase arm needs only `positions + evict_lag`.
/// A safe (slightly over-) estimate — admission reserves through it,
/// so over is the sound direction.
pub fn stream_pages_spec(
    page_cols: usize,
    cap: usize,
    positions: usize,
    evict_lag: usize,
) -> usize {
    let base = stream_pages(page_cols, cap, positions.saturating_add(evict_lag));
    if evict_lag == 0 {
        base
    } else {
        base + (evict_lag + page_cols - 1) / page_cols + 1
    }
}

/// Immutable pool geometry, shared by every handle clone.
#[derive(Debug, Clone, Copy)]
struct Geom {
    page_cols: usize,
    dh: usize,
    max_pages: usize,
    precision: Precision,
}

/// The pool's element stores — the only place element width exists.
/// Page/position arithmetic everywhere else is width-agnostic. Int8
/// keeps one f32 scale per K column and per V column (global column
/// index = element offset / `dh`), written by the quantizing push and
/// consumed by the attention core's f32 accumulation.
enum Store {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Int8 {
        k: Vec<i8>,
        v: Vec<i8>,
        ks: Vec<f32>,
        vs: Vec<f32>,
    },
}

/// Mutable pool state (behind the handle's mutex). The stores hold
/// `materialized * page_cols * dh` elements each; page `p` owns the
/// element span `[p * page_cols * dh, (p + 1) * page_cols * dh)` of
/// both K and V (and, at int8, the matching `page_cols` scales).
struct PoolInner {
    store: Store,
    /// Recycled page ids, LIFO so reuse stays cache-warm.
    free: Vec<u32>,
    /// Pages whose backing elements exist (monotone; never shrinks).
    materialized: usize,
    in_use: usize,
    /// Peak of `in_use` over the pool's life — the measured memory
    /// footprint the benches compare against ring preallocation.
    high_water: usize,
    /// Pages promised to admitted sessions (worst-case demand).
    reserved: usize,
}

impl PoolInner {
    fn alloc(&mut self, geom: &Geom) -> Option<u32> {
        let pid = match self.free.pop() {
            Some(pid) => pid,
            None => {
                if self.materialized >= geom.max_pages {
                    return None;
                }
                let pid = self.materialized as u32;
                self.materialized += 1;
                let elems = self.materialized * geom.page_cols * geom.dh;
                match &mut self.store {
                    Store::F32 { k, v } => {
                        k.resize(elems, 0.0);
                        v.resize(elems, 0.0);
                    }
                    Store::Int8 { k, v, ks, vs } => {
                        k.resize(elems, 0);
                        v.resize(elems, 0);
                        let cols = self.materialized * geom.page_cols;
                        ks.resize(cols, 0.0);
                        vs.resize(cols, 0.0);
                    }
                }
                pid
            }
        };
        self.in_use += 1;
        self.high_water = self.high_water.max(self.in_use);
        Some(pid)
    }

    fn free(&mut self, pid: u32) {
        debug_assert!((pid as usize) < self.materialized);
        self.free.push(pid);
        self.in_use -= 1;
    }
}

/// Point-in-time pool counters (pages). Each page stores `page_cols` K
/// columns *and* `page_cols` V columns of `dh` elements; element width
/// (and the physical bytes a page costs) follows from `precision` via
/// [`bytes_per_page`](PoolStats::bytes_per_page), while
/// [`floats_per_page`](PoolStats::floats_per_page) stays the
/// width-independent f32-equivalent measure (positions × dh × 2) the
/// occupancy comparisons are denominated in.
#[derive(Debug, Clone, Copy)]
pub struct PoolStats {
    pub page_cols: usize,
    pub dh: usize,
    pub max_pages: usize,
    pub precision: Precision,
    pub materialized: usize,
    pub in_use: usize,
    pub high_water: usize,
    pub reserved: usize,
    /// Free-list length (recycled pages awaiting reuse);
    /// `materialized == in_use + free_pages` always.
    pub free_pages: usize,
}

impl PoolStats {
    /// K + V *elements* one page stores (= floats at f32 precision —
    /// the f32-equivalent page size, independent of element width).
    pub fn floats_per_page(&self) -> usize {
        2 * self.page_cols * self.dh
    }

    /// Peak f32-equivalent elements ever live at once (the paged
    /// analog of "N preallocated rings") — what the serve CLI's
    /// `kv pool:` line and the serve bench's `paged_peak_kv_floats`
    /// report. Position-denominated: identical across precisions for
    /// the same push sequence.
    pub fn peak_floats(&self) -> usize {
        self.high_water * self.floats_per_page()
    }

    /// Physical bytes one page costs at this pool's precision: f32
    /// pages store 4 bytes per element; int8 pages store 1 byte per
    /// element plus one f32 scale per K column and per V column.
    pub fn bytes_per_page(&self) -> usize {
        match self.precision {
            Precision::F32 => 4 * self.floats_per_page(),
            Precision::Int8 => self.floats_per_page() + 2 * self.page_cols * 4,
        }
    }

    /// Peak physical bytes ever live at once — the quantized-occupancy
    /// number the serve CLI's `kv precision:` line and the benches'
    /// `bytes_per_session` report.
    pub fn peak_bytes(&self) -> usize {
        self.high_water * self.bytes_per_page()
    }
}

/// Shared page pool handle. Clones share the same pool; drop of the
/// last handle frees the backing stores.
#[derive(Clone)]
pub struct KvPool {
    geom: Geom,
    inner: Arc<Mutex<PoolInner>>,
}

impl KvPool {
    /// A f32 pool of at most `max_pages` pages, each holding
    /// `page_cols` K/V columns of `dh` floats. Backing memory is
    /// materialized lazily, page by page, so a large `max_pages` costs
    /// nothing until sessions actually write.
    pub fn new(page_cols: usize, dh: usize, max_pages: usize) -> Result<KvPool> {
        KvPool::with_precision(page_cols, dh, max_pages, Precision::F32)
    }

    /// [`KvPool::new`] with an explicit element precision. The pool's
    /// precision governs storage for every stream in it: pushes into
    /// an int8 pool quantize each K/V column (one scale per column),
    /// and the attention core dispatches on [`KvRead::store`]. Page
    /// counts, reservations and admission are position-denominated and
    /// identical across precisions.
    pub fn with_precision(
        page_cols: usize,
        dh: usize,
        max_pages: usize,
        precision: Precision,
    ) -> Result<KvPool> {
        if page_cols == 0 || dh == 0 || max_pages == 0 {
            bail!("KvPool: page_cols, dh and max_pages must all be >= 1");
        }
        let store = match precision {
            Precision::F32 => Store::F32 { k: Vec::new(), v: Vec::new() },
            Precision::Int8 => {
                Store::Int8 { k: Vec::new(), v: Vec::new(), ks: Vec::new(), vs: Vec::new() }
            }
        };
        Ok(KvPool {
            geom: Geom { page_cols, dh, max_pages, precision },
            inner: Arc::new(Mutex::new(PoolInner {
                store,
                free: Vec::new(),
                materialized: 0,
                in_use: 0,
                high_water: 0,
                reserved: 0,
            })),
        })
    }

    /// Default page width for a context of `cap` positions: fine
    /// enough that short sessions hold a fraction of a ring, coarse
    /// enough that page-table overhead stays negligible.
    pub fn default_page_cols(cap: usize) -> usize {
        (cap / 8).clamp(1, 16)
    }

    pub fn page_cols(&self) -> usize {
        self.geom.page_cols
    }

    pub fn dh(&self) -> usize {
        self.geom.dh
    }

    pub fn max_pages(&self) -> usize {
        self.geom.max_pages
    }

    pub fn precision(&self) -> Precision {
        self.geom.precision
    }

    /// [`stream_pages`] with this pool's page width.
    pub fn stream_pages(&self, cap: usize, positions: usize) -> usize {
        stream_pages(self.geom.page_cols, cap, positions)
    }

    /// Reserve `pages` for a session about to open; refuses (without
    /// reserving) when the pool cannot cover them on top of existing
    /// reservations.
    pub fn try_reserve(&self, pages: usize) -> bool {
        let mut inner = self.lock();
        if inner.reserved + pages > self.geom.max_pages {
            return false;
        }
        inner.reserved += pages;
        true
    }

    /// Return a reservation (session retired/cancelled/dropped).
    pub fn unreserve(&self, pages: usize) {
        let mut inner = self.lock();
        debug_assert!(inner.reserved >= pages);
        inner.reserved = inner.reserved.saturating_sub(pages);
    }

    /// Would [`try_reserve`](KvPool::try_reserve)`(pages)` succeed
    /// right now? The scheduler polls this before dequeuing a request
    /// so pool exhaustion defers admission instead of consuming the
    /// request.
    pub fn can_admit(&self, pages: usize) -> bool {
        self.lock().reserved + pages <= self.geom.max_pages
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.lock();
        PoolStats {
            page_cols: self.geom.page_cols,
            dh: self.geom.dh,
            max_pages: self.geom.max_pages,
            precision: self.geom.precision,
            materialized: inner.materialized,
            in_use: inner.in_use,
            high_water: inner.high_water,
            reserved: inner.reserved,
            free_pages: inner.free.len(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().expect("kv pool mutex poisoned")
    }
}

/// One row's page table: `pages[i]` backs logical page `first_lp + i`
/// (positions `lp * page_cols ..`). Contiguous by construction —
/// pushes arrive at consecutive positions and frees only pop the
/// front.
struct Stream {
    first_lp: usize,
    pages: VecDeque<u32>,
}

/// Paged K/V storage for one attention stream group (one layer × one
/// attention matrix) across a session's `rows` — the drop-in
/// replacement for the old `[rows, cap, dh]` ring pair. Holds a pool
/// handle; every held page returns to the pool on drop.
pub struct Kv {
    pool: KvPool,
    cap: usize,
    rows: usize,
    /// Window eviction trails the newest position by this many extra
    /// positions (0 = eager). See [`Kv::set_evict_lag`].
    evict_lag: usize,
    streams: Vec<Stream>,
}

impl Kv {
    pub fn new(pool: &KvPool, rows: usize, cap: usize) -> Kv {
        debug_assert!(rows > 0 && cap > 0);
        Kv {
            pool: pool.clone(),
            cap,
            rows,
            evict_lag: 0,
            streams: (0..rows).map(|_| Stream { first_lp: 0, pages: VecDeque::new() }).collect(),
        }
    }

    /// Speculative-decoding mode: keep window eviction `lag` positions
    /// behind the newest push. A verify step pushes up to `lag`
    /// positions past the committed stream and may then
    /// [`truncate_to`](Kv::truncate_to) the rejected suffix; with eager
    /// eviction those pushes could free pages the post-rollback window
    /// still needs. Lagged eviction guarantees any rollback of at most
    /// `lag` positions leaves the full attention window resident, at a
    /// bounded page cost priced by [`stream_pages_spec`]. Reads are
    /// unaffected (the attention core never looks below its window);
    /// stale pages are reclaimed by later pushes' slide loop.
    pub fn set_evict_lag(&mut self, lag: usize) {
        self.evict_lag = lag;
    }

    /// Store a chunk's `[rows, tn, dh]` K/V projections at positions
    /// `pos0 .. pos0 + tn` (consecutive across calls, except where a
    /// [`truncate_to`](Kv::truncate_to) rollback rewinds them). Pages
    /// that the post-write attention window no longer covers are freed
    /// back to the pool before the new position's page is allocated,
    /// so a same-stream slide can recycle its own page and the pool
    /// never sees more than [`stream_pages`] pages from this stream.
    ///
    /// # Panics
    /// If the pool is exhausted — unreachable when every session in
    /// the pool stays within the position budget it reserved.
    pub fn push(&mut self, kh: &[f32], vh: &[f32], tn: usize, pos0: usize) {
        let (pc, dh, cap) = (self.pool.page_cols(), self.pool.dh(), self.cap);
        debug_assert_eq!(kh.len(), self.rows * tn * dh, "push k chunk shape");
        debug_assert_eq!(vh.len(), self.rows * tn * dh, "push v chunk shape");
        let geom = self.pool.geom;
        let mut inner = self.pool.lock();
        for (bi, st) in self.streams.iter_mut().enumerate() {
            for ci in 0..tn {
                let p = pos0 + ci;
                // Slide the window: drop pages fully below the low
                // edge after this write lands (lag positions behind in
                // speculative mode, so rollbacks stay window-safe).
                let lo = (p + 1).saturating_sub(cap + self.evict_lag);
                while !st.pages.is_empty() && (st.first_lp + 1) * pc <= lo {
                    let pid = st.pages.pop_front().expect("non-empty page table");
                    inner.free(pid);
                    st.first_lp += 1;
                }
                let lp = p / pc;
                if st.pages.is_empty() {
                    st.first_lp = lp;
                }
                if lp >= st.first_lp + st.pages.len() {
                    debug_assert_eq!(
                        lp,
                        st.first_lp + st.pages.len(),
                        "positions must be pushed consecutively"
                    );
                    let pid = inner.alloc(&geom).unwrap_or_else(|| {
                        panic!(
                            "kv page pool exhausted ({} / {} pages in use, {} reserved): \
                             a session decoded past its reserved position budget",
                            inner.in_use, geom.max_pages, inner.reserved
                        )
                    });
                    st.pages.push_back(pid);
                }
                let pid = st.pages[lp - st.first_lp] as usize;
                let dst = (pid * pc + p % pc) * dh;
                let src = (bi * tn + ci) * dh;
                match &mut inner.store {
                    Store::F32 { k, v } => {
                        k[dst..dst + dh].copy_from_slice(&kh[src..src + dh]);
                        v[dst..dst + dh].copy_from_slice(&vh[src..src + dh]);
                    }
                    Store::Int8 { k, v, ks, vs } => {
                        // One scale per column; codes and scale are a
                        // pure function of the f32 input, so re-pushes
                        // (chunk replay, speculative rollback) write
                        // byte-identical pages.
                        ks[dst / dh] = quantize_row_into(&mut k[dst..dst + dh], &kh[src..src + dh]);
                        vs[dst / dh] = quantize_row_into(&mut v[dst..dst + dh], &vh[src..src + dh]);
                    }
                }
            }
        }
    }

    /// Roll the stream back so `len` positions (`0..len`) remain
    /// committed: every page whose span lies entirely at positions
    /// `>= len` is freed back to the pool. A page straddling `len`
    /// stays (its live prefix is still addressable); its stale suffix
    /// columns are simply overwritten when pushes resume at `len`.
    /// This is the speculative-decode rollback — the caller must only
    /// truncate positions it has not let eviction reach, i.e. at most
    /// the configured [`evict lag`](Kv::set_evict_lag) behind the
    /// newest push.
    pub fn truncate_to(&mut self, len: usize) {
        let pc = self.pool.page_cols();
        // First logical page fully at positions >= len.
        let keep_lp = (len + pc - 1) / pc;
        let mut inner = self.pool.lock();
        for st in self.streams.iter_mut() {
            while st.first_lp + st.pages.len() > keep_lp {
                let pid = st.pages.pop_back().expect("non-empty page table");
                inner.free(pid);
            }
        }
    }

    /// Flat float offset of position `pos` of row `row` in the pool
    /// stores — pure page-table math, no lock. The position must be
    /// inside the row's live window (pushed, not yet slid out).
    #[inline]
    pub fn locate(&self, row: usize, pos: usize) -> usize {
        let pc = self.pool.page_cols();
        let st = &self.streams[row];
        debug_assert!(pos / pc >= st.first_lp, "position below the live window");
        let pid = st.pages[pos / pc - st.first_lp] as usize;
        (pid * pc + pos % pc) * self.pool.dh()
    }

    /// Call `f(jj, base)` for every position `lo + jj` in `lo..=hi`
    /// (ascending — the attention core's summation order), with `base`
    /// the [`locate`](Kv::locate) offset of that position's column.
    /// Columns within a page are contiguous, so each page is resolved
    /// once per run instead of once per column — the hot read path of
    /// `attend`. Lock-free, like `locate`; the window must be live.
    #[inline]
    pub fn for_window(&self, row: usize, lo: usize, hi: usize, mut f: impl FnMut(usize, usize)) {
        let (pc, dh) = (self.pool.page_cols(), self.pool.dh());
        let st = &self.streams[row];
        let mut pos = lo;
        let mut jj = 0usize;
        while pos <= hi {
            let lp = pos / pc;
            debug_assert!(lp >= st.first_lp, "position below the live window");
            let pid = st.pages[lp - st.first_lp] as usize;
            let run_end = ((lp + 1) * pc - 1).min(hi);
            let mut base = (pid * pc + pos % pc) * dh;
            while pos <= run_end {
                f(jj, base);
                jj += 1;
                base += dh;
                pos += 1;
            }
        }
    }

    /// Borrow the pool stores for reading (holds the pool lock for the
    /// view's lifetime). The attention core captures the raw slices
    /// and resolves columns via [`Kv::for_window`] / [`Kv::locate`],
    /// so pool workers never touch the mutex.
    pub fn read(&self) -> KvRead<'_> {
        KvRead(self.pool.lock())
    }

    /// Pages currently held across all rows (tests/introspection).
    pub fn pages_held(&self) -> usize {
        self.streams.iter().map(|s| s.pages.len()).sum()
    }

    /// Structural audit for the serve layer's per-tick invariant
    /// auditor: with `len` committed positions per row (positions are
    /// pushed strictly increasing, so `len` is also the newest
    /// position + 1), every row's page table must
    ///
    /// * be empty iff `len == 0`,
    /// * end at the page of the newest committed position (pushes
    ///   allocated it; truncation keeps it),
    /// * still cover the attention window's low edge (eviction only
    ///   frees pages fully below the lagged low edge),
    /// * hold no more pages than the [`stream_pages_spec`] bound the
    ///   session reserved through, and
    /// * map no page id twice and none outside the pool.
    ///
    /// Violations return a structured error naming the broken
    /// invariant; this never panics and never takes the pool lock.
    pub fn audit(&self, len: usize) -> Result<()> {
        let pc = self.pool.page_cols();
        let bound = stream_pages_spec(pc, self.cap, usize::MAX, self.evict_lag);
        let mut pids: Vec<u32> = Vec::new();
        for (bi, st) in self.streams.iter().enumerate() {
            if len == 0 {
                if !st.pages.is_empty() {
                    bail!("kv audit: row {bi} holds {} pages before any push", st.pages.len());
                }
                continue;
            }
            if st.pages.is_empty() {
                bail!("kv audit: row {bi} lost its page table at {len} committed positions");
            }
            let top_lp = st.first_lp + st.pages.len() - 1;
            if top_lp != (len - 1) / pc {
                bail!(
                    "kv audit: row {bi} top page {top_lp} != newest position's page {} \
                     ({len} committed, {pc} cols/page)",
                    (len - 1) / pc
                );
            }
            let win_lo = len.saturating_sub(self.cap);
            if st.first_lp > win_lo / pc {
                bail!(
                    "kv audit: row {bi} first page {} is above the attention window's low \
                     edge page {} — a live column was evicted",
                    st.first_lp,
                    win_lo / pc
                );
            }
            if st.pages.len() > bound {
                bail!(
                    "kv audit: row {bi} holds {} pages, over the {bound}-page reservation \
                     bound (cap {}, lag {})",
                    st.pages.len(),
                    self.cap,
                    self.evict_lag
                );
            }
            pids.extend(st.pages.iter().copied());
        }
        pids.sort_unstable();
        if pids.windows(2).any(|w| w[0] == w[1]) {
            bail!("kv audit: a pool page is mapped by two rows");
        }
        if let Some(&top) = pids.last() {
            if top as usize >= self.pool.max_pages() {
                bail!("kv audit: page id {top} outside the pool's {} pages", self.pool.max_pages());
            }
        }
        Ok(())
    }
}

impl Drop for Kv {
    /// Every held page goes back to the pool — cancelled and retired
    /// sessions restore the free list in full.
    fn drop(&mut self) {
        let mut inner = self.pool.lock();
        for st in &mut self.streams {
            while let Some(pid) = st.pages.pop_front() {
                inner.free(pid);
            }
        }
    }
}

/// A read view over the pool's K/V stores (the pool lock, held until
/// drop).
pub struct KvRead<'a>(MutexGuard<'a, PoolInner>);

impl KvRead<'_> {
    /// `(k_store, v_store)` of a **f32** pool — index with
    /// [`Kv::locate`] offsets. Panics on an int8 pool; precision-aware
    /// readers use [`store`](KvRead::store) instead.
    pub fn slices(&self) -> (&[f32], &[f32]) {
        match &self.0.store {
            Store::F32 { k, v } => (k.as_slice(), v.as_slice()),
            Store::Int8 { .. } => {
                panic!("KvRead::slices on an int8 pool — dispatch on KvRead::store")
            }
        }
    }

    /// Precision-dispatched view of the stores. Element offsets from
    /// [`Kv::locate`] / [`Kv::for_window`] index `k`/`v` identically
    /// in both arms; at int8 the column's scale sits at
    /// `offset / dh` in `ks`/`vs`.
    pub fn store(&self) -> StoreView<'_> {
        match &self.0.store {
            Store::F32 { k, v } => StoreView::F32 { k, v },
            Store::Int8 { k, v, ks, vs } => StoreView::Int8 { k, v, ks, vs },
        }
    }
}

/// Borrowed, precision-tagged K/V stores (see [`KvRead::store`]).
#[derive(Clone, Copy)]
pub enum StoreView<'a> {
    F32 {
        k: &'a [f32],
        v: &'a [f32],
    },
    Int8 {
        k: &'a [i8],
        v: &'a [i8],
        /// Per-K-column scales, indexed by element offset / `dh`.
        ks: &'a [f32],
        /// Per-V-column scales, indexed by element offset / `dh`.
        vs: &'a [f32],
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_pages_bounds() {
        // Growing phase: aligned span from 0.
        assert_eq!(stream_pages(4, 16, 1), 1);
        assert_eq!(stream_pages(4, 16, 4), 1);
        assert_eq!(stream_pages(4, 16, 5), 2);
        assert_eq!(stream_pages(4, 16, 16), 4);
        // Windowed phase: ceil((cap-1)/pc) + 1.
        assert_eq!(stream_pages(4, 16, 100), 5);
        assert_eq!(stream_pages(16, 16, usize::MAX), 2);
        assert_eq!(stream_pages(1, 1, usize::MAX), 1);
        // Odd page width straddles.
        assert_eq!(stream_pages(7, 16, 17), 3);
        assert_eq!(stream_pages(7, 16, usize::MAX), 4);
    }

    #[test]
    fn reservation_accounting() {
        let pool = KvPool::new(4, 8, 10).unwrap();
        assert!(pool.can_admit(10));
        assert!(pool.try_reserve(6));
        assert!(!pool.can_admit(5));
        assert!(!pool.try_reserve(5), "over-reserve must refuse");
        assert_eq!(pool.stats().reserved, 6, "failed reserve must not leak");
        assert!(pool.try_reserve(4));
        pool.unreserve(10);
        assert_eq!(pool.stats().reserved, 0);
    }

    #[test]
    fn push_read_roundtrip_across_pages_and_window() {
        let (pc, dh, cap) = (2usize, 3usize, 6usize);
        let pool = KvPool::new(pc, dh, 8).unwrap();
        let mut kv = Kv::new(&pool, 1, cap);
        // Push 10 positions one at a time; position p stores value
        // p*10+j so every column is distinguishable.
        let col = |p: usize, neg: bool| -> Vec<f32> {
            (0..dh).map(|j| (p * 10 + j) as f32 * if neg { -1.0 } else { 1.0 }).collect()
        };
        for p in 0..10usize {
            kv.push(&col(p, false), &col(p, true), 1, p);
            // The live window after writing p is [lo, p].
            let lo = (p + 1).saturating_sub(cap);
            assert!(
                kv.pages_held() <= stream_pages(pc, cap, cap + 1),
                "held {} pages at p={p}",
                kv.pages_held()
            );
            let view = kv.read();
            let (ks, vs) = view.slices();
            for q in lo..=p {
                let at = kv.locate(0, q);
                assert_eq!(&ks[at..at + dh], col(q, false).as_slice(), "k at pos {q}");
                assert_eq!(&vs[at..at + dh], col(q, true).as_slice(), "v at pos {q}");
            }
            // The run-based enumeration must yield exactly locate's
            // offsets, in ascending position order.
            let mut seen = Vec::new();
            kv.for_window(0, lo, p, |jj, base| seen.push((jj, base)));
            let want: Vec<(usize, usize)> =
                (lo..=p).enumerate().map(|(jj, q)| (jj, kv.locate(0, q))).collect();
            assert_eq!(seen, want, "for_window diverged from locate at p={p}");
        }
        // The stream never exceeded its windowed worst case, and drop
        // returns everything.
        let before = pool.stats();
        assert!(before.high_water <= stream_pages(pc, cap, usize::MAX));
        drop(kv);
        let after = pool.stats();
        assert_eq!(after.in_use, 0);
        assert_eq!(after.free_pages, after.materialized, "drop must restore the free list");
    }

    #[test]
    fn multi_row_streams_are_independent() {
        let (pc, dh, cap) = (2usize, 2usize, 4usize);
        let pool = KvPool::new(pc, dh, 16).unwrap();
        let mut kv = Kv::new(&pool, 2, cap);
        // One chunk push of 3 positions for both rows: [rows, tn, dh].
        let mk = |base: f32| (0..2 * 3 * dh).map(|i| base + i as f32).collect::<Vec<f32>>();
        let (kh, vh) = (mk(100.0), mk(500.0));
        kv.push(&kh, &vh, 3, 0);
        let view = kv.read();
        let (ks, _) = view.slices();
        for bi in 0..2 {
            for ci in 0..3 {
                let at = kv.locate(bi, ci);
                let src = (bi * 3 + ci) * dh;
                assert_eq!(&ks[at..at + dh], &kh[src..src + dh], "row {bi} pos {ci}");
            }
        }
    }

    #[test]
    fn stream_pages_spec_dominates_eager_bound() {
        for &pc in &[1usize, 3, 4, 16] {
            for &cap in &[1usize, 4, 16, 64] {
                for &lag in &[0usize, 1, 2, 5, 9] {
                    for &pos in &[1usize, 3, 17, usize::MAX] {
                        let spec = stream_pages_spec(pc, cap, pos, lag);
                        assert!(
                            spec >= stream_pages(pc, cap, pos),
                            "pc={pc} cap={cap} lag={lag} pos={pos}"
                        );
                        // The analytical worst case under lagged
                        // eviction: a span of cap + lag live positions
                        // plus one page of boundary slop each side.
                        let span = cap + lag;
                        let worst = (span + pc - 1) / pc + 1;
                        assert!(spec >= worst.min(stream_pages_spec(pc, cap, usize::MAX, lag)));
                    }
                }
            }
        }
        assert_eq!(stream_pages_spec(4, 16, usize::MAX, 0), stream_pages(4, 16, usize::MAX));
    }

    /// The speculative rollback satellite: interleave push / truncate /
    /// push across page boundaries at several page widths and check
    /// that (a) every committed column stays readable and exact,
    /// (b) freed tail pages actually return to the pool, and (c) the
    /// stream never exceeds its [`stream_pages_spec`] reservation.
    #[test]
    fn truncate_to_returns_pages_and_preserves_columns() {
        for &pc in &[1usize, 3, 16] {
            let (dh, cap, lag) = (2usize, 8usize, 5usize);
            let pool = KvPool::new(pc, dh, 64).unwrap();
            let mut kv = Kv::new(&pool, 1, cap);
            kv.set_evict_lag(lag);
            let col = |p: usize, ver: usize, neg: bool| -> Vec<f32> {
                (0..dh)
                    .map(|j| (p * 100 + ver * 10 + j) as f32 * if neg { -1.0 } else { 1.0 })
                    .collect()
            };
            // committed[p] = version written at position p, for live checks.
            let mut committed: Vec<usize> = Vec::new();
            let mut push_at = |kv: &mut Kv, committed: &mut Vec<usize>, p: usize, ver: usize| {
                kv.push(&col(p, ver, false), &col(p, ver, true), 1, p);
                committed.truncate(p);
                committed.push(ver);
            };
            let check = |kv: &Kv, committed: &[usize]| {
                let last = committed.len() - 1;
                let lo = committed.len().saturating_sub(cap);
                let view = kv.read();
                let (ks, vs) = view.slices();
                for q in lo..=last {
                    let at = kv.locate(0, q);
                    assert_eq!(&ks[at..at + dh], col(q, committed[q], false).as_slice());
                    assert_eq!(&vs[at..at + dh], col(q, committed[q], true).as_slice());
                }
            };
            // Grow to 7, roll back to 4 (crosses a page boundary at
            // every pc in {1, 3, 16}), regrow with fresh values, then
            // push far enough that the lagged window slides.
            for p in 0..7 {
                push_at(&mut kv, &mut committed, p, 1);
            }
            check(&kv, &committed);
            let held_before = kv.pages_held();
            kv.truncate_to(4);
            committed.truncate(4);
            let freed = held_before - kv.pages_held();
            assert_eq!(freed, held_before - (4 + pc - 1) / pc, "pc={pc} tail pages freed");
            assert!(pool.stats().free_pages >= freed, "freed pages must hit the free list");
            check(&kv, &committed);
            for p in 4..9 {
                push_at(&mut kv, &mut committed, p, 2);
            }
            check(&kv, &committed);
            // Second rollback inside the same page, then a long run:
            // the lagged stream must stay within its spec reservation.
            kv.truncate_to(7);
            committed.truncate(7);
            for p in 7..40 {
                push_at(&mut kv, &mut committed, p, 3);
                assert!(
                    kv.pages_held() <= stream_pages_spec(pc, cap, usize::MAX, lag),
                    "pc={pc} p={p} held {} over spec bound",
                    kv.pages_held()
                );
                check(&kv, &committed);
            }
            drop(kv);
            let st = pool.stats();
            assert_eq!(st.in_use, 0, "pc={pc} drop must return everything");
            assert_eq!(st.free_pages, st.materialized);
        }
    }

    #[test]
    fn kv_audit_accepts_live_streams_and_catches_corruption() {
        let (pc, dh, cap, lag) = (2usize, 2usize, 4usize, 3usize);
        let pool = KvPool::new(pc, dh, 64).unwrap();
        let mut kv = Kv::new(&pool, 2, cap);
        kv.set_evict_lag(lag);
        kv.audit(0).expect("fresh stream audits clean");
        let chunk = vec![0.5f32; 2 * dh];
        for p in 0..12usize {
            kv.push(&chunk, &chunk, 1, p);
            kv.audit(p + 1).expect("live stream audits clean");
        }
        kv.truncate_to(10);
        kv.audit(10).expect("post-rollback stream audits clean");
        // Wrong committed length: the top page no longer matches.
        assert!(kv.audit(12).is_err(), "stale length must fail the audit");
        // Corrupt a page table: duplicate a page across rows.
        let dup = kv.streams[0].pages[0];
        kv.streams[1].pages[0] = dup;
        assert!(kv.audit(10).is_err(), "duplicate page id must fail the audit");
    }

    /// Satellite pin: capacity is position-denominated, not
    /// byte-denominated. An int8 pool must hold exactly the same
    /// *positions* per page as a f32 twin for the same push sequence —
    /// identical pages_held at every step, identical high water,
    /// identical `stream_pages` bounds — while each page's physical
    /// bytes shrink, and every quantized column must round-trip within
    /// its scale/2 bound.
    #[test]
    fn int8_pages_hold_same_positions_per_page() {
        // dh = 8: the int8 byte ratio per column is (dh + 4) / (4 * dh)
        // = 0.375, strictly under the < 0.5 assertion below (dh = 4
        // would sit exactly at 0.5).
        let (pc, dh, cap) = (3usize, 8usize, 8usize);
        let pf = KvPool::new(pc, dh, 32).unwrap();
        let pq = KvPool::with_precision(pc, dh, 32, Precision::Int8).unwrap();
        assert_eq!(pf.precision(), Precision::F32);
        assert_eq!(pq.precision(), Precision::Int8);
        let mut kf = Kv::new(&pf, 1, cap);
        let mut kq = Kv::new(&pq, 1, cap);
        let col = |p: usize| -> Vec<f32> {
            (0..dh).map(|j| ((p * 7 + j) as f32 - 5.0) * 0.25).collect()
        };
        for p in 0..20usize {
            kf.push(&col(p), &col(p), 1, p);
            kq.push(&col(p), &col(p), 1, p);
            assert_eq!(kf.pages_held(), kq.pages_held(), "pages diverged at p={p}");
            assert_eq!(kf.locate(0, p), kq.locate(0, p), "offsets diverged at p={p}");
            // The quantized column reconstructs within scale/2.
            let view = kq.read();
            match view.store() {
                StoreView::Int8 { k, ks, .. } => {
                    let at = kq.locate(0, p);
                    let s = ks[at / dh];
                    let want = col(p);
                    for j in 0..dh {
                        assert!((k[at + j] as f32 * s - want[j]).abs() <= s / 2.0 + 1e-7);
                    }
                }
                StoreView::F32 { .. } => panic!("int8 pool must expose an int8 store"),
            }
        }
        let (sf, sq) = (pf.stats(), pq.stats());
        assert_eq!(sf.high_water, sq.high_water, "page high water must match");
        assert_eq!(sf.peak_floats(), sq.peak_floats(), "f32-equivalent peak must match");
        assert!(
            2 * sq.peak_bytes() < sf.peak_bytes(),
            "int8 peak bytes {} not < half of f32 {}",
            sq.peak_bytes(),
            sf.peak_bytes()
        );
        assert_eq!(
            stream_pages(pc, cap, usize::MAX),
            pq.stream_pages(cap, usize::MAX),
            "reservation math is precision-invariant"
        );
    }

    #[test]
    fn pool_materializes_lazily_and_recycles() {
        let pool = KvPool::new(2, 2, 100).unwrap();
        assert_eq!(pool.stats().materialized, 0, "no upfront allocation");
        let mut kv = Kv::new(&pool, 1, 4);
        for p in 0..20usize {
            kv.push(&[1.0, 2.0], &[3.0, 4.0], 1, p);
        }
        let st = pool.stats();
        // Window cap 4, pages of 2: at most ceil(3/2)+1 = 3 live, and
        // recycling means materialization stops there too.
        assert!(st.high_water <= 3, "high water {}", st.high_water);
        assert!(st.materialized <= 3, "materialized {}", st.materialized);
        assert!(st.peak_floats() <= 3 * 2 * 2 * 2);
    }
}
