//! Native attention forward passes: SwitchHead MoE attention (paper
//! §2.2, Eq. 7-10), the dense MHA baseline, and the MoA baseline — all
//! three positional schemes (Transformer-XL relative, RoPE, none).
//!
//! Operation-for-operation mirror of `python/compile/layers.py` (the
//! JAX reference) with dropout elided (this backend is inference/eval
//! only); the numpy twin `python/tools/native_ref.py` cross-checks the
//! agreement. Every multiply-accumulate is tallied into a
//! [`MacCounter`] so the measured cost of a forward pass can be
//! compared against the analytic `macs::attention_cost` (Eq. 11-15).
//!
//! Execution rides the [`crate::kernels`] layer: projections are
//! blocked/parallel (MoE ones expert-grouped), the attention core and
//! XL positional logits shard over query rows, per-layer invariants
//! (`base_bias`, the sinusoidal distance embedding) are hoisted out of
//! the per-head loop, and temporaries cycle through the scratch arena.
//! All of it is bit-identical to the scalar reference order, so the
//! golden vectors pin this path unchanged.

use crate::config::{ModelConfig, Positional};
use crate::kernels::{par_rows_mut, scratch};
use crate::model::params::{DenseP, MoaP, Proj, SwitchHeadP};
use crate::model::tensor::{
    matmul, moe_matmul, rope_rotate, route, sinusoidal, softmax_rows, MacCounter, Router, NEG_INF,
};

/// Per-layer analysis output (attention maps + router scores), the
/// native analog of the PJRT `attn` entry's outputs.
#[derive(Default)]
pub struct LayerAux {
    /// One `[b, t, tk]` map per attention matrix (head, or MoA slot).
    pub attn: Vec<Vec<f32>>,
    /// Router score tensors: (name, data `[n, e]` flattened, e).
    pub gates: Vec<(String, Vec<f32>, usize)>,
}

/// Shared geometry for one attention call.
pub struct AttnCtx<'a> {
    pub b: usize,
    pub t: usize,
    pub tk: usize,
    /// Key-side validity mask `[b * tk]` (true = attend); listops only.
    pub pad_mask: Option<&'a [bool]>,
}

/// Dense-or-MoE projection application with MAC accounting (shared
/// with the incremental decoder in `model::decode`).
pub(crate) fn proj(
    x: &[f32],
    p: &Proj,
    idx: &[usize],
    gate: &[f32],
    k: usize,
    macs: &mut MacCounter,
) -> Vec<f32> {
    let n = x.len() / p.rows;
    if p.moe {
        // k expert matmuls + the gate multiply per output element
        // (the `(D + 1)` factor of Eq. 13).
        macs.proj_moe += (n * k * (p.rows * p.cols + p.cols)) as f64;
        moe_matmul(x, &p.experts, p.rows, p.cols, idx, gate, k)
    } else {
        macs.proj_dense += (n * p.rows * p.cols) as f64;
        matmul(x, &p.experts[0], n, p.rows, p.cols)
    }
}

/// Quantized [`proj`]: identical dispatch shape and MAC tallies with
/// the expert bank stored as per-row-scaled i8 ([`QuantProj`]). The
/// dequant multiply replaces the f32 weight load, so the analytic MAC
/// accounting is unchanged — only storage and memory traffic differ.
pub(crate) fn proj_q(
    x: &[f32],
    qp: &crate::model::params::QuantProj,
    idx: &[usize],
    gate: &[f32],
    k: usize,
    macs: &mut MacCounter,
) -> Vec<f32> {
    let (rows, cols) = (qp.experts[0].rows, qp.experts[0].cols);
    let n = x.len() / rows;
    if qp.moe {
        macs.proj_moe += (n * k * (rows * cols + cols)) as f64;
        crate::model::tensor::moe_matmul_q(x, &qp.experts, rows, cols, idx, gate, k)
    } else {
        macs.proj_dense += (n * rows * cols) as f64;
        crate::model::tensor::matmul_q(x, &qp.experts[0], n, rows, cols)
    }
}

/// Base additive bias `[b, t, tk]`: causal mask (skipped for pos=none,
/// the bidirectional encoder) plus the padding key-mask. Identical for
/// every head of a layer — callers compute it once per layer.
fn base_bias(pos: Positional, ctx: &AttnCtx) -> Vec<f32> {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let mut bias = scratch::take(b * t * tk);
    if pos != Positional::None {
        let off = tk - t;
        for bi in 0..b {
            for i in 0..t {
                let row = &mut bias[(bi * t + i) * tk..(bi * t + i + 1) * tk];
                for (j, v) in row.iter_mut().enumerate() {
                    if j > i + off {
                        *v += NEG_INF;
                    }
                }
            }
        }
    }
    if let Some(pm) = ctx.pad_mask {
        for bi in 0..b {
            for i in 0..t {
                let row = &mut bias[(bi * t + i) * tk..(bi * t + i + 1) * tk];
                for (j, v) in row.iter_mut().enumerate() {
                    if !pm[bi * tk + j] {
                        *v += NEG_INF;
                    }
                }
            }
        }
    }
    bias
}

/// Add the Transformer-XL relative-position logits: entry (i, j) gains
/// `(q_i + v) . r_{clip(i + off - j)}` (mirrors `layers.xl_pos_bias`).
/// Sharded over the `b * t` query rows.
fn add_xl_pos(
    bias: &mut [f32],
    q: &[f32],  // [b, t, dh] — pre-u_bias queries
    vb: &[f32], // [dh]
    r: &[f32],  // [tk, dh] — projected distance embeddings
    ctx: &AttnCtx,
    dh: usize,
    macs: &mut MacCounter,
) {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let off = tk as isize - t as isize;
    par_rows_mut(bias, tk, tk * dh, |row, brow| {
        let i = row % t;
        let qrow = &q[row * dh..(row + 1) * dh];
        for (j, bv) in brow.iter_mut().enumerate() {
            let dist = (i as isize + off - j as isize).clamp(0, tk as isize - 1) as usize;
            let rrow = &r[dist * dh..(dist + 1) * dh];
            let mut s = 0f32;
            for d0 in 0..dh {
                s += (qrow[d0] + vb[d0]) * rrow[d0];
            }
            *bv += s;
        }
    });
    macs.pos += (b * t * tk * dh) as f64;
}

/// Attention core for one head: softmax(q k^T * scale + bias) v,
/// sharded over the `b * t` query rows (each row's logits, softmax and
/// value reduction are self-contained, so sharding never reorders a
/// sum). Returns `[b, t, dh]`; appends the `[b, t, tk]` map when
/// collecting.
fn attention_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bias: &[f32],
    ctx: &AttnCtx,
    dh: usize,
    macs: &mut MacCounter,
    collect: Option<&mut LayerAux>,
) -> Vec<f32> {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut att = scratch::take(b * t * dh);
    let mut maps = collect.as_ref().map(|_| vec![0f32; b * t * tk]);
    let maps_ptr = maps.as_mut().map(|m| crate::kernels::SendPtr(m.as_mut_ptr()));
    par_rows_mut(&mut att, dh, 2 * tk * dh, |row, orow| {
        let bi = row / t;
        let qrow = &q[row * dh..(row + 1) * dh];
        let mut logits = scratch::take(tk);
        for (j, lv) in logits.iter_mut().enumerate() {
            let krow = &k[(bi * tk + j) * dh..(bi * tk + j + 1) * dh];
            let mut s = 0f32;
            for d0 in 0..dh {
                s += qrow[d0] * krow[d0];
            }
            *lv = s * scale + bias[row * tk + j];
        }
        softmax_rows(&mut logits, tk);
        if let Some(mp) = maps_ptr {
            // SAFETY: map rows mirror the disjoint output rows.
            unsafe { mp.row(row * tk, tk) }.copy_from_slice(&logits);
        }
        for (j, &w) in logits.iter().enumerate() {
            let vrow = &v[(bi * tk + j) * dh..(bi * tk + j + 1) * dh];
            for d0 in 0..dh {
                orow[d0] += w * vrow[d0];
            }
        }
        scratch::put(logits);
    });
    macs.attn_core += 2.0 * (b * t * tk * dh) as f64;
    if let (Some(aux), Some(m)) = (collect, maps) {
        aux.attn.push(m);
    }
    att
}

/// SwitchHead MoE attention (Eq. 7-10). `x_ln` `[b, t, d]` is the
/// layer-normed block input (destination side); `src` `[b, tk, d]` is
/// the XL cache concatenated with `x_ln` (source side).
#[allow(clippy::too_many_arguments)]
pub fn switchhead_attention(
    cfg: &ModelConfig,
    p: &SwitchHeadP,
    x_ln: &[f32],
    src: &[f32],
    ctx: &AttnCtx,
    macs: &mut MacCounter,
    mut collect: Option<&mut LayerAux>,
) -> Vec<f32> {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let (d, dh, h, e, k) = (cfg.d_model, cfg.d_head, cfg.n_heads, cfg.att_n_experts, cfg.att_k);
    let router = Router::parse(&cfg.att_router);
    // Per-layer invariants, identical across heads: the sinusoidal
    // distance embedding and the mask-only base bias.
    let dist_emb = (cfg.pos == Positional::Xl).then(|| sinusoidal(tk, d));
    let base = base_bias(cfg.pos, ctx);

    let mut y = scratch::take(b * t * d);
    for hi in 0..h {
        // Routing: source side gates K/V experts, destination side Q/O.
        let want_scores = collect.is_some();
        let (idx_s, gate_s, sc_s) = route(src, &p.w_sel_s[hi], d, e, k, router, want_scores, macs);
        let w_sel_d = match &p.w_sel_d {
            Some(sels) => &sels[hi],
            None => &p.w_sel_s[hi], // shared_selection (paper §3.6)
        };
        let (idx_d, gate_d, sc_d) = route(x_ln, w_sel_d, d, e, k, router, want_scores, macs);
        if let Some(aux) = collect.as_deref_mut() {
            aux.gates.push((format!("gate_src_{hi}"), sc_s.unwrap(), e));
            aux.gates.push((format!("gate_dst_{hi}"), sc_d.unwrap(), e));
        }

        let mut kh = proj(src, &p.w_k[hi], &idx_s, &gate_s, k, macs);
        let mut qh = proj(x_ln, &p.w_q[hi], &idx_d, &gate_d, k, macs);
        let vh = proj(src, &p.w_v[hi], &idx_s, &gate_s, k, macs);

        let mut xl_bias = None;
        match cfg.pos {
            Positional::Xl => {
                let xl = p.xl.as_ref().expect("xl params");
                let r = matmul(dist_emb.as_ref().unwrap(), &xl.w_kr[hi], tk, d, dh);
                macs.pos += (tk * d * dh) as f64;
                let mut bias = scratch::take(base.len());
                bias.copy_from_slice(&base);
                add_xl_pos(&mut bias, &qh, &xl.v[hi], &r, ctx, dh, macs);
                scratch::put(r);
                add_bias_rows(&mut qh, &xl.u[hi], dh);
                xl_bias = Some(bias);
            }
            Positional::Rope => {
                rope_rotate(&mut qh, b, t, dh, tk - t);
                rope_rotate(&mut kh, b, tk, dh, 0);
            }
            Positional::None => {}
        }

        let bias = xl_bias.as_deref().unwrap_or(&base);
        let att = attention_core(&qh, &kh, &vh, bias, ctx, dh, macs, collect.as_deref_mut());
        if let Some(bias) = xl_bias {
            scratch::put(bias);
        }
        scratch::put(qh);
        scratch::put(kh);
        scratch::put(vh);
        let yo = proj(&att, &p.w_o[hi], &idx_d, &gate_d, k, macs);
        scratch::put(att);
        for (yv, ov) in y.iter_mut().zip(&yo) {
            *yv += ov;
        }
        scratch::put(yo);
    }
    if let Some(de) = dist_emb {
        scratch::put(de);
    }
    scratch::put(base);
    y
}

/// Standard multi-head attention baseline (Eq. 1-3).
pub fn dense_attention(
    cfg: &ModelConfig,
    p: &DenseP,
    x_ln: &[f32],
    src: &[f32],
    ctx: &AttnCtx,
    macs: &mut MacCounter,
    mut collect: Option<&mut LayerAux>,
) -> Vec<f32> {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let (d, dh, h) = (cfg.d_model, cfg.d_head, cfg.n_heads);
    let dist_emb = (cfg.pos == Positional::Xl).then(|| sinusoidal(tk, d));
    let base = base_bias(cfg.pos, ctx);

    let mut y = scratch::take(b * t * d);
    for hi in 0..h {
        let mut qh = matmul(x_ln, &p.w_q[hi], b * t, d, dh);
        let mut kh = matmul(src, &p.w_k[hi], b * tk, d, dh);
        let vh = matmul(src, &p.w_v[hi], b * tk, d, dh);
        macs.proj_dense += ((b * t + 2 * b * tk) * d * dh) as f64;

        let mut xl_bias = None;
        match cfg.pos {
            Positional::Xl => {
                let xl = p.xl.as_ref().expect("xl params");
                let r = matmul(dist_emb.as_ref().unwrap(), &xl.w_kr[hi], tk, d, dh);
                macs.pos += (tk * d * dh) as f64;
                let mut bias = scratch::take(base.len());
                bias.copy_from_slice(&base);
                add_xl_pos(&mut bias, &qh, &xl.v[hi], &r, ctx, dh, macs);
                scratch::put(r);
                add_bias_rows(&mut qh, &xl.u[hi], dh);
                xl_bias = Some(bias);
            }
            Positional::Rope => {
                rope_rotate(&mut qh, b, t, dh, tk - t);
                rope_rotate(&mut kh, b, tk, dh, 0);
            }
            Positional::None => {}
        }

        let bias = xl_bias.as_deref().unwrap_or(&base);
        let att = attention_core(&qh, &kh, &vh, bias, ctx, dh, macs, collect.as_deref_mut());
        if let Some(bias) = xl_bias {
            scratch::put(bias);
        }
        scratch::put(qh);
        scratch::put(kh);
        scratch::put(vh);
        let yo = matmul(&att, &p.w_o[hi], b * t, dh, d);
        scratch::put(att);
        macs.proj_dense += (b * t * dh * d) as f64;
        for (yv, ov) in y.iter_mut().zip(&yo) {
            *yv += ov;
        }
        scratch::put(yo);
    }
    if let Some(de) = dist_emb {
        scratch::put(de);
    }
    scratch::put(base);
    y
}

/// MoA baseline: shared K/V, `moa_k` active query/output experts per
/// token, each computing its own attention matrix (Eq. 14-15 cost).
pub fn moa_attention(
    cfg: &ModelConfig,
    p: &MoaP,
    x_ln: &[f32],
    src: &[f32],
    ctx: &AttnCtx,
    macs: &mut MacCounter,
    mut collect: Option<&mut LayerAux>,
) -> Vec<f32> {
    let (b, t, tk) = (ctx.b, ctx.t, ctx.tk);
    let (d, dh, e, k) = (cfg.d_model, cfg.d_head, cfg.moa_n_experts, cfg.moa_k);

    let (idx, gate, _) = route(x_ln, &p.w_sel, d, e, k, Router::Softmax, false, macs);
    let mut kk = matmul(src, &p.w_k, b * tk, d, dh);
    let vv = matmul(src, &p.w_v, b * tk, d, dh);
    macs.proj_dense += (2 * b * tk * d * dh) as f64;

    let r = match cfg.pos {
        Positional::Xl => {
            let de = sinusoidal(tk, d);
            macs.pos += (tk * d * dh) as f64;
            let r = matmul(&de, p.xl.as_ref().expect("xl params").w_kr[0].as_slice(), tk, d, dh);
            scratch::put(de);
            Some(r)
        }
        Positional::Rope => {
            rope_rotate(&mut kk, b, tk, dh, 0);
            None
        }
        Positional::None => None,
    };
    let base = base_bias(cfg.pos, ctx);

    let n = b * t;
    let ones = vec![1.0f32; n];
    let mut y = scratch::take(n * d);
    for j in 0..k {
        // Slot j: per-token expert idx[:, j]; query gate is 1, the
        // output projection carries the routing gate (as in layers.py).
        let idx_j: Vec<usize> = (0..n).map(|i| idx[i * k + j]).collect();
        let gate_j: Vec<f32> = (0..n).map(|i| gate[i * k + j]).collect();
        let mut qj = moe_matmul(x_ln, &p.w_q, d, dh, &idx_j, &ones, 1);
        macs.proj_moe += (n * (d * dh + dh)) as f64;
        let mut xl_bias = None;
        match cfg.pos {
            Positional::Xl => {
                let xl = p.xl.as_ref().expect("xl params");
                let mut bias = scratch::take(base.len());
                bias.copy_from_slice(&base);
                add_xl_pos(&mut bias, &qj, &xl.v[0], r.as_ref().unwrap(), ctx, dh, macs);
                add_bias_rows(&mut qj, &xl.u[0], dh);
                xl_bias = Some(bias);
            }
            Positional::Rope => {
                rope_rotate(&mut qj, b, t, dh, tk - t);
            }
            Positional::None => {}
        }
        let bias = xl_bias.as_deref().unwrap_or(&base);
        let att = attention_core(&qj, &kk, &vv, bias, ctx, dh, macs, collect.as_deref_mut());
        if let Some(bias) = xl_bias {
            scratch::put(bias);
        }
        scratch::put(qj);
        let yo = moe_matmul(&att, &p.w_o, dh, d, &idx_j, &gate_j, 1);
        scratch::put(att);
        macs.proj_moe += (n * (dh * d + d)) as f64;
        for (yv, ov) in y.iter_mut().zip(&yo) {
            *yv += ov;
        }
        scratch::put(yo);
    }
    scratch::put(kk);
    scratch::put(vv);
    if let Some(r) = r {
        scratch::put(r);
    }
    scratch::put(base);
    y
}

/// Add a per-feature bias vector to every `dh`-row (u_bias application).
fn add_bias_rows(x: &mut [f32], bias: &[f32], dh: usize) {
    for row in x.chunks_mut(dh) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}
