//! `NativeEngine` — the artifact-free execution backend.
//!
//! Wraps a [`NativeModel`] behind the typed inference API the runtime
//! layer defines ([`crate::runtime::Backend`]: `score`, `next_logits`,
//! `open_session`, plus attention/gate analysis), so the zero-shot
//! scorer, the generator and the benches run on either backend
//! unchanged. Everything executes on host f32 buffers — no artifacts,
//! no Python, no PJRT. Stateful generation goes through
//! [`NativeSession`], the incremental decoder with the expert-sparse
//! KV cache.
//!
//! Compute runs on the [`crate::kernels`] layer: blocked parallel
//! matmuls, expert-grouped MoE dispatch and the scratch arena, sized
//! by `PALLAS_THREADS` (see `kernels::set_threads`). Results are
//! bit-identical to the single-threaded scalar reference at every
//! thread count, so the golden vectors hold regardless of machine.

use crate::config::{ModelConfig, Task};
use crate::coordinator::analysis::HostArray;
use crate::model::block::{self, EncodeAux};
use crate::model::decode::NativeSession;
use crate::model::params::NativeModel;
use crate::model::tensor::MacCounter;
use crate::runtime::api::{Backend, Logits, ScoreOut, Session, TokenBatch};
use crate::util::error::{bail, Result};

pub struct NativeEngine {
    pub model: NativeModel,
}

impl NativeEngine {
    /// Build a fresh (seed-initialized) native model for `cfg`.
    pub fn new(cfg: &ModelConfig, seed: u64) -> Result<NativeEngine> {
        cfg.validate()?;
        Ok(NativeEngine { model: NativeModel::init(cfg, seed) })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn check_batch(&self, batch: &TokenBatch, want_cols: usize) -> Result<usize> {
        if batch.width() != want_cols {
            bail!("native engine: expected width {want_cols}, got {}", batch.width());
        }
        batch.check_vocab(self.cfg().vocab_size)?;
        Ok(batch.rows())
    }

    /// Per-position next-token log-probabilities for a `[B, T+1]`
    /// window (same contract as the PJRT `score` entry).
    pub fn score(&self, batch: &TokenBatch) -> Result<ScoreOut> {
        if self.cfg().task != Task::Lm {
            bail!("score requires an LM config");
        }
        let b = self.check_batch(batch, self.cfg().seq_len + 1)?;
        let mut macs = MacCounter::default();
        let logp = block::score(&self.model, batch.tokens(), b, &mut macs);
        ScoreOut::new(logp, b, self.cfg().seq_len)
    }

    /// Logits for the token following a `[B, T]` window.
    pub fn next_logits(&self, batch: &TokenBatch) -> Result<Logits> {
        if self.cfg().task != Task::Lm {
            bail!("next_logits requires an LM config");
        }
        let b = self.check_batch(batch, self.cfg().seq_len)?;
        let mut macs = MacCounter::default();
        let logits = block::next_logits(&self.model, batch.tokens(), b, &mut macs);
        Logits::new(logits, b, self.cfg().vocab_size)
    }

    /// ListOps classification logits, one `[n_classes]` row per batch
    /// row.
    pub fn class_logits(&self, batch: &TokenBatch) -> Result<Logits> {
        if self.cfg().task != Task::ListOps {
            bail!("class_logits requires a listops config");
        }
        let b = self.check_batch(batch, self.cfg().seq_len)?;
        let mut macs = MacCounter::default();
        let logits = block::class_logits(&self.model, batch.tokens(), b, &mut macs);
        Logits::new(logits, b, self.cfg().ls_n_classes)
    }

    /// Total negative log-likelihood and token count over a `[B, T+1]`
    /// window (the native analog of the PJRT eval_step metrics).
    pub fn eval_nll(&self, batch: &TokenBatch) -> Result<(f64, usize)> {
        let out = self.score(batch)?;
        let sum: f64 = out.data().iter().map(|&x| -(x as f64)).sum();
        Ok((sum, out.data().len()))
    }

    /// Attention maps and router scores, shaped like the PJRT `attn`
    /// entry outputs: `attn` is `[L, B, H, T, Tk]` (H = attention
    /// matrices per layer), gates are `[L, N, E]` per router.
    /// LM configs take a `[B, T+1]` window (last column dropped, as in
    /// `model.py::attn_maps`); listops takes `[B, T]`.
    pub fn attention_arrays(&self, batch: &TokenBatch) -> Result<Vec<HostArray>> {
        let cfg = self.cfg().clone();
        let t = cfg.seq_len;
        let mut aux = EncodeAux::default();
        let mut macs = MacCounter::default();
        let b;
        let tokens = batch.tokens();
        match cfg.task {
            Task::Lm => {
                b = self.check_batch(batch, t + 1)?;
                let mut inp = Vec::with_capacity(b * t);
                for bi in 0..b {
                    inp.extend_from_slice(&tokens[bi * (t + 1)..bi * (t + 1) + t]);
                }
                block::encode(&self.model, &inp, b, t, None, &mut macs, Some(&mut aux));
            }
            Task::ListOps => {
                b = self.check_batch(batch, t)?;
                let pad_mask: Vec<bool> = tokens.iter().map(|&tok| tok != 0).collect();
                let aux_ref = Some(&mut aux);
                block::encode(&self.model, tokens, b, t, Some(&pad_mask), &mut macs, aux_ref);
            }
        }

        let l = aux.layers.len();
        let n_mat = aux.layers.first().map(|la| la.attn.len()).unwrap_or(0);
        let tk = cfg.ctx_len();
        let mut out = Vec::new();

        // Stack per-layer, per-head maps into [L, B, H, T, Tk].
        let mut maps = vec![0f32; l * b * n_mat * t * tk];
        for (li, la) in aux.layers.iter().enumerate() {
            for (hi, m) in la.attn.iter().enumerate() {
                for bi in 0..b {
                    let src = &m[bi * t * tk..(bi + 1) * t * tk];
                    let dst = (((li * b + bi) * n_mat + hi) * t) * tk;
                    maps[dst..dst + t * tk].copy_from_slice(src);
                }
            }
        }
        out.push(HostArray {
            name: "out/attn".into(),
            shape: vec![l, b, n_mat, t, tk],
            data: maps,
        });

        // Stack gate tensors by name into [L, N, E].
        if let Some(first) = aux.layers.first() {
            for (gi, (name, _, e)) in first.gates.iter().enumerate() {
                let n = first.gates[gi].1.len() / e;
                let mut data = Vec::with_capacity(l * n * e);
                for la in &aux.layers {
                    data.extend_from_slice(&la.gates[gi].1);
                }
                out.push(HostArray {
                    name: format!("out/{name}"),
                    shape: vec![l, n, *e],
                    data,
                });
            }
        }
        Ok(out)
    }

    /// MAC count of one full forward pass (batch 1, all layers), by
    /// category — compared against `macs::model_attention_cost` in the
    /// property tests.
    pub fn count_macs(&self) -> Result<MacCounter> {
        let cfg = self.cfg();
        let t = cfg.seq_len;
        let mut macs = MacCounter::default();
        match cfg.task {
            Task::Lm => {
                let tokens = vec![1i32; t];
                block::encode(&self.model, &tokens, 1, t, None, &mut macs, None);
            }
            Task::ListOps => {
                let tokens = vec![1i32; t];
                let pad_mask = vec![true; t];
                block::encode(&self.model, &tokens, 1, t, Some(&pad_mask), &mut macs, None);
            }
        }
        Ok(macs)
    }
}

impl Backend for NativeEngine {
    fn score(&self, batch: &TokenBatch) -> Result<ScoreOut> {
        NativeEngine::score(self, batch)
    }

    fn next_logits(&self, batch: &TokenBatch) -> Result<Logits> {
        NativeEngine::next_logits(self, batch)
    }

    fn open_session(&self, rows: usize) -> Result<Box<dyn Session + '_>> {
        Ok(Box::new(NativeSession::open(&self.model, rows)?))
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}
