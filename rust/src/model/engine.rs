//! `NativeEngine` — the artifact-free execution backend.
//!
//! Wraps a [`NativeModel`] behind the same host-buffer inference API the
//! PJRT [`crate::runtime::Engine`] exposes (`score`, `next_logits`,
//! attention/gate analysis), implementing [`crate::runtime::Backend`] so
//! the zero-shot scorer, the generator and the benches run on either
//! backend unchanged. Everything executes on host f32 buffers — no
//! artifacts, no Python, no PJRT.

use crate::config::{ModelConfig, Task};
use crate::coordinator::analysis::HostArray;
use crate::model::block::{self, EncodeAux};
use crate::model::params::NativeModel;
use crate::model::tensor::MacCounter;
use crate::runtime::Backend;
use crate::util::error::{bail, Result};

pub struct NativeEngine {
    pub model: NativeModel,
}

impl NativeEngine {
    /// Build a fresh (seed-initialized) native model for `cfg`.
    pub fn new(cfg: &ModelConfig, seed: u64) -> Result<NativeEngine> {
        cfg.validate()?;
        Ok(NativeEngine { model: NativeModel::init(cfg, seed) })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn check_tokens(&self, tokens: &[i32], dims: &[usize], want_cols: usize) -> Result<usize> {
        let cfg = self.cfg();
        if dims.len() != 2 || dims[1] != want_cols {
            bail!("native engine: expected dims [B, {want_cols}], got {dims:?}");
        }
        let b = dims[0];
        if tokens.len() != b * want_cols {
            bail!("native engine: token buffer {} != {b}x{want_cols}", tokens.len());
        }
        for &t in tokens {
            if t < 0 || t as usize >= cfg.vocab_size {
                bail!("native engine: token id {t} outside vocab {}", cfg.vocab_size);
            }
        }
        Ok(b)
    }

    /// Per-position next-token log-probabilities for a `[B, T+1]`
    /// window; returns `[B * T]` (same contract as `Engine::score`).
    pub fn score(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        if self.cfg().task != Task::Lm {
            bail!("score requires an LM config");
        }
        let b = self.check_tokens(tokens, dims, self.cfg().seq_len + 1)?;
        let mut macs = MacCounter::default();
        Ok(block::score(&self.model, tokens, b, &mut macs))
    }

    /// Logits for the token following a `[B, T]` window; `[B * V]`.
    pub fn next_logits(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        if self.cfg().task != Task::Lm {
            bail!("next_logits requires an LM config");
        }
        let b = self.check_tokens(tokens, dims, self.cfg().seq_len)?;
        let mut macs = MacCounter::default();
        Ok(block::next_logits(&self.model, tokens, b, &mut macs))
    }

    /// ListOps classification logits `[B, n_classes]`.
    pub fn class_logits(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        if self.cfg().task != Task::ListOps {
            bail!("class_logits requires a listops config");
        }
        let b = self.check_tokens(tokens, dims, self.cfg().seq_len)?;
        let mut macs = MacCounter::default();
        Ok(block::class_logits(&self.model, tokens, b, &mut macs))
    }

    /// Total negative log-likelihood and token count over a `[B, T+1]`
    /// window (the native analog of the PJRT eval_step metrics).
    pub fn eval_nll(&self, tokens: &[i32], dims: &[usize]) -> Result<(f64, usize)> {
        let logp = self.score(tokens, dims)?;
        let sum: f64 = logp.iter().map(|&x| -(x as f64)).sum();
        Ok((sum, logp.len()))
    }

    /// Attention maps and router scores, shaped like the PJRT `attn`
    /// entry outputs: `attn` is `[L, B, H, T, Tk]` (H = attention
    /// matrices per layer), gates are `[L, N, E]` per router.
    /// LM configs take a `[B, T+1]` window (last column dropped, as in
    /// `model.py::attn_maps`); listops takes `[B, T]`.
    pub fn attention_arrays(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<HostArray>> {
        let cfg = self.cfg().clone();
        let t = cfg.seq_len;
        let mut aux = EncodeAux::default();
        let mut macs = MacCounter::default();
        let b;
        match cfg.task {
            Task::Lm => {
                b = self.check_tokens(tokens, dims, t + 1)?;
                let mut inp = Vec::with_capacity(b * t);
                for bi in 0..b {
                    inp.extend_from_slice(&tokens[bi * (t + 1)..bi * (t + 1) + t]);
                }
                block::encode(&self.model, &inp, b, t, None, &mut macs, Some(&mut aux));
            }
            Task::ListOps => {
                b = self.check_tokens(tokens, dims, t)?;
                let pad_mask: Vec<bool> = tokens.iter().map(|&tok| tok != 0).collect();
                block::encode(&self.model, tokens, b, t, Some(&pad_mask), &mut macs, Some(&mut aux));
            }
        }

        let l = aux.layers.len();
        let n_mat = aux.layers.first().map(|la| la.attn.len()).unwrap_or(0);
        let tk = cfg.ctx_len();
        let mut out = Vec::new();

        // Stack per-layer, per-head maps into [L, B, H, T, Tk].
        let mut maps = vec![0f32; l * b * n_mat * t * tk];
        for (li, la) in aux.layers.iter().enumerate() {
            for (hi, m) in la.attn.iter().enumerate() {
                for bi in 0..b {
                    let src = &m[bi * t * tk..(bi + 1) * t * tk];
                    let dst = (((li * b + bi) * n_mat + hi) * t) * tk;
                    maps[dst..dst + t * tk].copy_from_slice(src);
                }
            }
        }
        out.push(HostArray {
            name: "out/attn".into(),
            shape: vec![l, b, n_mat, t, tk],
            data: maps,
        });

        // Stack gate tensors by name into [L, N, E].
        if let Some(first) = aux.layers.first() {
            for (gi, (name, _, e)) in first.gates.iter().enumerate() {
                let n = first.gates[gi].1.len() / e;
                let mut data = Vec::with_capacity(l * n * e);
                for la in &aux.layers {
                    data.extend_from_slice(&la.gates[gi].1);
                }
                out.push(HostArray {
                    name: format!("out/{name}"),
                    shape: vec![l, n, *e],
                    data,
                });
            }
        }
        Ok(out)
    }

    /// MAC count of one full forward pass (batch 1, all layers), by
    /// category — compared against `macs::model_attention_cost` in the
    /// property tests.
    pub fn count_macs(&self) -> Result<MacCounter> {
        let cfg = self.cfg();
        let t = cfg.seq_len;
        let mut macs = MacCounter::default();
        match cfg.task {
            Task::Lm => {
                let tokens = vec![1i32; t];
                block::encode(&self.model, &tokens, 1, t, None, &mut macs, None);
            }
            Task::ListOps => {
                let tokens = vec![1i32; t];
                let pad_mask = vec![true; t];
                block::encode(&self.model, &tokens, 1, t, Some(&pad_mask), &mut macs, None);
            }
        }
        Ok(macs)
    }
}

impl Backend for NativeEngine {
    fn score(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        NativeEngine::score(self, tokens, dims)
    }

    fn next_logits(&self, tokens: &[i32], dims: &[usize]) -> Result<Vec<f32>> {
        NativeEngine::next_logits(self, tokens, dims)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}
