//! SwitchHead: Mixture-of-Experts attention (Csordás et al., NeurIPS 2024)
//! — full-system reproduction with a **two-backend** execution
//! architecture.
//!
//! # Backends
//!
//! * **Native** ([`model`]): a pure-Rust, dependency-free reference
//!   implementation of the SwitchHead/SwitchAll forward pass (MoE
//!   attention with per-head sigmoid expert selection, σ-MoE
//!   feedforward, XL/RoPE positional schemes). Always available; runs
//!   `score`/`next_logits`/analysis on host f32 buffers.
//! * **PJRT** ([`runtime::Engine`]): replays HLO artifacts AOT-compiled
//!   by the Python/JAX side (`python/compile/aot.py`, Pallas σ-MoE
//!   kernels) and owns training via the device-resident flat
//!   training-state buffer. Requires `make artifacts`; in offline
//!   builds the `xla` crate is stubbed (`runtime::xla_stub`).
//!
//! Both implement [`runtime::Backend`] — typed requests/responses
//! ([`runtime::TokenBatch`], [`runtime::Logits`], [`runtime::ScoreOut`])
//! plus the stateful [`runtime::Session`] prefill/decode API — so the
//! zero-shot harness, the generator and the benches run on either.
//! Incremental generation is native-backend accelerated: an
//! expert-sparse **paged** KV cache ([`model::kv_cache`], behind
//! [`model::NativeSession`]) makes a decode step O(context) instead of
//! a full-window recompute while holding only the pages the live
//! attention window touches; PJRT sessions fall back to windowed
//! recompute transparently.
//! The native hot path executes on [`kernels`] — cache-blocked,
//! `PALLAS_THREADS`-parallel matmul and expert-grouped MoE dispatch,
//! bit-identical to the scalar reference at every thread count.
//! Above the sessions sits [`serve`], the continuous-batching layer:
//! a bounded request queue plus a scheduler that fuses every live
//! session's next token into one forward per tick
//! ([`model::decode_batched`]), so the expert-grouped dispatch runs
//! over the union of (session, head, expert) selections instead of
//! single-token batches — with admission capacity-aware over the
//! shared KV page pool. [`spec`] adds draft-and-verify speculative
//! decoding on the same fused path: a tiny draft model proposes k
//! tokens per session, one width-(k+1) fused verify step checks them
//! all, and the accept walk keeps emitted streams bit-identical to
//! non-speculative decoding. [`obs`] watches all of it —
//! request-lifecycle traces, online latency histograms and MoE routing
//! telemetry — without ever changing a stream. [`quant`] gives the
//! whole stack an int8 storage mode (`--precision int8` /
//! `PALLAS_PRECISION`): expert weight banks and paged K/V pages stored
//! as per-row-scaled i8 with every reduction still accumulating in
//! f32, while the f32 path stays byte-for-byte untouched as the
//! oracle. `docs/ARCHITECTURE.md` is the end-to-end tour.
//!
//! # Artifact-free test tier
//!
//! `make check` (`cargo build --release && cargo test -q`) needs only a
//! Rust toolchain: PJRT integration tests skip when `artifacts/` is
//! absent, while golden-vector tests (`rust/tests/golden/`, generated
//! by `python/tools/gen_native_golden.py` and cross-validated against
//! the JAX reference) and the MoE routing property tests exercise the
//! native backend deterministically.
//!
//! # Layers
//!
//! * L1/L2 (Python, build-time only): Pallas σ-MoE kernels and the JAX
//!   model zoo, AOT-lowered to HLO text by `python/compile/aot.py`.
//! * Runtime: [`runtime`] — backend seam, PJRT engine, manifest,
//!   checkpoints; [`model`] — the native backend.
//! * L3 (this crate): configuration, data pipeline, training
//!   coordinator, analytic MAC/memory accounting, evaluation and
//!   zero-shot harnesses, analysis tooling and the bench drivers.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod macs;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod spec;
pub mod util;

/// Repo-relative default locations (overridable via CLI flags).
pub mod paths {
    pub const ARTIFACTS: &str = "artifacts";
    pub const CONFIGS: &str = "configs";
    pub const RUNS: &str = "runs";
}
