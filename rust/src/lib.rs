//! SwitchHead: Mixture-of-Experts attention (Csordás et al., NeurIPS 2024)
//! — full-system reproduction as a three-layer Rust + JAX + Pallas stack.
//!
//! * L1/L2 (Python, build-time only): Pallas σ-MoE kernels and the JAX
//!   model zoo, AOT-lowered to HLO text by `python/compile/aot.py`.
//! * Runtime: [`runtime`] loads the artifacts through the PJRT CPU
//!   client and chains the device-resident flat training-state buffer.
//! * L3 (this crate): configuration, data pipeline, training
//!   coordinator, analytic MAC/memory accounting, evaluation and
//!   zero-shot harnesses, analysis tooling and the bench drivers.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod macs;
pub mod runtime;
pub mod util;

/// Repo-relative default locations (overridable via CLI flags).
pub mod paths {
    pub const ARTIFACTS: &str = "artifacts";
    pub const CONFIGS: &str = "configs";
    pub const RUNS: &str = "runs";
}
