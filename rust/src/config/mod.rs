//! Typed model/run configuration, shared with the Python compile path via
//! the same `configs/*.json` files. Unknown keys are ignored on both
//! sides, so a single file can carry model hyperparameters (Python) and
//! run/data settings (Rust).

use crate::util::error::{bail, Result};

use crate::util::json::Json;

/// Attention family — mirrors `python/compile/layers.py::ModelConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    SwitchHead,
    Dense,
    Moa,
}

impl Family {
    pub fn parse(s: &str) -> Result<Family> {
        Ok(match s {
            "switchhead" => Family::SwitchHead,
            "dense" => Family::Dense,
            "moa" => Family::Moa,
            other => bail!("unknown family '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Family::SwitchHead => "switchhead",
            Family::Dense => "dense",
            Family::Moa => "moa",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Positional {
    Xl,
    Rope,
    None,
}

impl Positional {
    pub fn parse(s: &str) -> Result<Positional> {
        Ok(match s {
            "xl" => Positional::Xl,
            "rope" => Positional::Rope,
            "none" => Positional::None,
            other => bail!("unknown positional scheme '{other}'"),
        })
    }

    /// Context multiple C (paper A.2): XL attends over C*T keys.
    pub fn context_multiple(&self) -> usize {
        match self {
            Positional::Xl => 2,
            _ => 1,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Lm,
    ListOps,
}

/// Storage precision for the bulk inference tensors (expert weight
/// banks and paged K/V pages — see [`crate::quant`]). `F32` is the
/// oracle path; `Int8` stores those tensors as per-row-scaled i8 while
/// every reduction still accumulates in f32. Routing, layer norms and
/// positional tables always stay f32, so routing arithmetic itself
/// adds no quantization error (selections follow the activations,
/// which quantized matmuls perturb within the documented band).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f32" => Precision::F32,
            "int8" => Precision::Int8,
            other => bail!("unknown precision '{other}' (expected f32|int8)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    /// `PALLAS_PRECISION` (f32|int8), defaulting to f32. This is the
    /// default for any config that does not name a `"precision"` key,
    /// which is how `make check` re-runs whole suites quantized.
    pub fn from_env() -> Precision {
        crate::util::cli::env_parsed("PALLAS_PRECISION", Precision::F32, |s| {
            Precision::parse(s).map_err(|e| e.to_string())
        })
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub family: Family,
    pub pos: Positional,
    pub task: Task,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub dropout: f64,
    // SwitchHead
    pub att_n_experts: usize,
    pub att_k: usize,
    /// Routing activation (paper design choice): "sigmoid" = sigma-MoE
    /// non-competitive (default), "softmax" = MoA-style competitive.
    pub att_router: String,
    pub moe_v: bool,
    pub moe_k: bool,
    pub moe_q: bool,
    pub moe_o: bool,
    pub shared_selection: bool,
    // MoA
    pub moa_n_experts: usize,
    pub moa_k: usize,
    // MLP
    pub mlp_type: MlpType,
    pub mlp_n_experts: usize,
    pub mlp_k: usize,
    pub mlp_d_expert: usize,
    // training
    pub lr: f64,
    pub warmup: usize,
    pub clip: f64,
    pub ls_n_classes: usize,
    // run/data settings (Rust only)
    pub dataset: String,
    pub train_steps: usize,
    /// Inference storage precision (weights + paged KV). JSON key
    /// `"precision"`; absent → `PALLAS_PRECISION` env → f32.
    pub precision: Precision,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MlpType {
    Dense,
    SigmaMoe,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let mlp_type = match j.get_or_str("mlp_type", "dense").as_str() {
            "dense" => MlpType::Dense,
            "sigma_moe" => MlpType::SigmaMoe,
            other => bail!("unknown mlp_type '{other}'"),
        };
        let task = match j.get_or_str("task", "lm").as_str() {
            "lm" => Task::Lm,
            "listops" => Task::ListOps,
            other => bail!("unknown task '{other}'"),
        };
        let precision = match j.get_or_str("precision", "").as_str() {
            "" => Precision::from_env(),
            s => Precision::parse(s)?,
        };
        Ok(ModelConfig {
            name: j.get_or_str("name", "unnamed"),
            family: Family::parse(&j.get_or_str("family", "switchhead"))?,
            pos: Positional::parse(&j.get_or_str("pos", "xl"))?,
            task,
            vocab_size: j.get_or_usize("vocab_size", 512),
            d_model: j.get_or_usize("d_model", 128),
            n_layers: j.get_or_usize("n_layers", 2),
            n_heads: j.get_or_usize("n_heads", 2),
            d_head: j.get_or_usize("d_head", 32),
            d_ff: j.get_or_usize("d_ff", 256),
            seq_len: j.get_or_usize("seq_len", 64),
            batch_size: j.get_or_usize("batch_size", 4),
            dropout: j.get_or_f64("dropout", 0.0),
            att_n_experts: j.get_or_usize("att_n_experts", 4),
            att_k: j.get_or_usize("att_k", 2),
            att_router: j.get_or_str("att_router", "sigmoid"),
            moe_v: j.get_or_bool("moe_v", true),
            moe_k: j.get_or_bool("moe_k", false),
            moe_q: j.get_or_bool("moe_q", false),
            moe_o: j.get_or_bool("moe_o", true),
            shared_selection: j.get_or_bool("shared_selection", false),
            moa_n_experts: j.get_or_usize("moa_n_experts", 8),
            moa_k: j.get_or_usize("moa_k", 2),
            mlp_type,
            mlp_n_experts: j.get_or_usize("mlp_n_experts", 4),
            mlp_k: j.get_or_usize("mlp_k", 2),
            mlp_d_expert: j.get_or_usize("mlp_d_expert", 64),
            lr: j.get_or_f64("lr", 2.5e-4),
            warmup: j.get_or_usize("warmup", 100),
            clip: j.get_or_f64("clip", 0.25),
            ls_n_classes: j.get_or_usize("ls_n_classes", 10),
            dataset: j.get_or_str("dataset", "wt103"),
            train_steps: j.get_or_usize("train_steps", 400),
            precision,
        })
    }

    pub fn load(path: &str) -> Result<ModelConfig> {
        let cfg = ModelConfig::from_json(&Json::parse_file(path)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.att_k > self.att_n_experts {
            bail!("att_k ({}) > att_n_experts ({})", self.att_k, self.att_n_experts);
        }
        if self.moa_k > self.moa_n_experts {
            bail!("moa_k > moa_n_experts");
        }
        if self.mlp_k > self.mlp_n_experts {
            bail!("mlp_k > mlp_n_experts");
        }
        if !matches!(self.att_router.as_str(), "sigmoid" | "softmax") {
            bail!("att_router must be sigmoid or softmax");
        }
        if self.d_model == 0 || self.n_layers == 0 || self.seq_len == 0 || self.batch_size == 0 {
            bail!("zero-sized model dimension");
        }
        if self.task == Task::ListOps && self.pos != Positional::None {
            bail!("listops task requires pos='none' (bidirectional encoder)");
        }
        if self.task == Task::Lm && self.pos == Positional::None {
            // pos='none' also disables the causal mask (layers.py treats
            // it as the bidirectional-encoder mode), so an LM would see
            // its own prediction targets — next-token scores would be
            // meaningless.
            bail!("lm task requires a causal positional scheme (pos='xl' or 'rope')");
        }
        Ok(())
    }

    /// Key/value context length (XL: cached chunk + current chunk).
    pub fn ctx_len(&self) -> usize {
        self.pos.context_multiple() * self.seq_len
    }

    /// Number of attention matrices computed per layer — the paper's
    /// headline resource metric ("up to 8x fewer").
    pub fn attention_matrices(&self) -> usize {
        match self.family {
            Family::Moa => self.moa_k,
            _ => self.n_heads,
        }
    }

    /// K/V cache streams per layer of a decoding session: MoA shares
    /// one K/V across its routed queries, every other family caches
    /// per head. Sizes the paged KV pool (`model::kv_cache`).
    pub fn kv_streams(&self) -> usize {
        match self.family {
            Family::Moa => 1,
            _ => self.n_heads,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_json() -> Json {
        Json::parse(
            r#"{"name":"t","family":"switchhead","pos":"xl","task":"lm",
                "vocab_size":512,"d_model":128,"n_layers":2,"n_heads":2,
                "d_head":32,"d_ff":256,"seq_len":64,"batch_size":4,
                "att_n_experts":4,"att_k":2}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let cfg = ModelConfig::from_json(&tiny_json()).unwrap();
        assert_eq!(cfg.family, Family::SwitchHead);
        assert_eq!(cfg.ctx_len(), 128);
        assert_eq!(cfg.attention_matrices(), 2);
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_bad_topk() {
        let mut j = tiny_json();
        j.set("att_k", Json::Num(9.0));
        let cfg = ModelConfig::from_json(&j).unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn precision_key_parses_and_rejects_unknown() {
        let mut j = tiny_json();
        j.set("precision", Json::Str("int8".into()));
        assert_eq!(ModelConfig::from_json(&j).unwrap().precision, Precision::Int8);
        j.set("precision", Json::Str("f32".into()));
        assert_eq!(ModelConfig::from_json(&j).unwrap().precision, Precision::F32);
        j.set("precision", Json::Str("fp16".into()));
        assert!(ModelConfig::from_json(&j).is_err());
        assert_eq!(Precision::Int8.name(), "int8");
    }

    #[test]
    fn moa_counts_active_experts_as_matrices() {
        let mut j = tiny_json();
        j.set("family", Json::Str("moa".into()));
        j.set("moa_n_experts", Json::Num(8.0));
        j.set("moa_k", Json::Num(3.0));
        let cfg = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg.attention_matrices(), 3);
    }
}
