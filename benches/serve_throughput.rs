//! Serving-layer load generator: aggregate decode throughput,
//! time-to-first-token (TTFT), and inter-token latency (ITL) of the
//! continuous-batching scheduler (one fused forward per tick over
//! every live session) versus the serial per-session loop the same
//! traffic would cost without batching.
//!
//! For each config it drives N concurrent greedy requests two ways
//! (identical synthetic traffic via `serve::load`, shared with the
//! `serve` CLI subcommand):
//!
//! * **serial** — one request at a time: prefill (timed — its TTFT),
//!   then single-row decode steps (each timed — the ITL
//!   distribution).
//! * **batched** — all N through `serve::Scheduler` with bounded-queue
//!   backpressure; a token produced in a tick inherits that tick's
//!   fused-step duration (`TickReport::decode_seconds`) as its ITL,
//!   and each request's TTFT is its submit→first-token wall time
//!   (`GenOutput::ttft_s`).
//!
//! Both paths must produce identical token streams (asserted — greedy
//! decoding plus the bit-identical fused step make this exact), so the
//! comparison is pure execution strategy. A **spec** scenario runs the
//! same traffic through a draft-and-verify scheduler
//! (`Scheduler::with_draft`, 1-layer draft from
//! `configs/tiny-sh-draft.json`, width `SPEC_K`): streams are asserted
//! identical to serial again, and the JSON reports the acceptance
//! rate, the draft/step/overhead time split, the scheduler's
//! `scheduler_overhead` op tally, and the measured **break-even
//! acceptance** — the rate above which speculation beats plain fused
//! decoding at this draft/target cost ratio. A separate **head-of-line**
//! scenario pins what chunked prefill buys: short decoding requests
//! co-resident with one ctx-length prompt, run with a small
//! `prefill_chunk` vs a monolithic one — per-tick prefill work is
//! asserted bounded by the chunk, and the co-resident ITL tail is
//! reported for both. The batched run also reports KV memory: the
//! paged pool's peak floats (`paged_peak_kv_floats`) against the
//! preallocated-ring formula the pre-paging design pinned
//! (`ring_kv_floats`). An **obs** scenario re-runs the batched traffic
//! with both observability sinks on (JSONL metrics + Chrome trace,
//! under `target/`) and the global MoE routing collector enabled:
//! streams are asserted bit-identical to the obs-off run, histogram
//! counts are asserted to reconcile exactly with `ServeStats`, and the
//! JSON reports the sink's measured per-tick overhead
//! (`obs_overhead_pct`) plus a routing-balance summary (per-layer
//! selection entropy, hottest-expert share, fused-dispatch union
//! fraction). Every number lands in
//! `BENCH_serve_throughput.json` (`target/…smoke.json` under
//! `SWITCHHEAD_BENCH_SMOKE=1`, which `make check` runs 1-threaded with
//! 4 concurrent tiny-sh requests; the smoke run also asserts the
//! TTFT/ITL fields are present in the emitted JSON).

use std::time::Instant;

use switchhead::bench::Table;
use switchhead::config::{ModelConfig, Task};
use switchhead::coordinator::generate::sample_logits;
use switchhead::kernels;
use switchhead::model::{NativeEngine, PoolStats};
use switchhead::runtime::{Backend, Session, TokenBatch};
use switchhead::obs::{routing, ObsOpts};
use switchhead::serve::{
    drive, synth_requests, FaultPlan, FinishReason, GenRequest, SamplingParams, Scheduler,
    ServeHists, ServeOpts, ServeStats, SAMPLE_STREAM,
};
use switchhead::util::json::Json;
use switchhead::util::rng::Pcg;
use switchhead::util::stats::{max_share, normalized_entropy, quantile};

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn str_(s: &str) -> Json {
    Json::Str(s.to_string())
}

struct RunResult {
    token_streams: Vec<Vec<i32>>,
    total_tokens: usize,
    secs: f64,
    /// Per-token (inter-token) latency samples, milliseconds.
    lat_ms: Vec<f64>,
    /// Per-request time-to-first-token samples, milliseconds.
    ttft_ms: Vec<f64>,
}

/// The no-batching baseline: each request decoded to completion on its
/// own single-row session, one at a time.
fn run_serial(engine: &NativeEngine, reqs: &[GenRequest]) -> RunResult {
    let t0 = Instant::now();
    let mut lat_ms = Vec::new();
    let mut ttft_ms = Vec::new();
    let mut token_streams = Vec::with_capacity(reqs.len());
    let mut total_tokens = 0usize;
    for r in reqs {
        let ta = Instant::now();
        let mut session = engine.open_session(1).unwrap();
        let batch = TokenBatch::new(r.prompt.clone(), 1, r.prompt.len()).unwrap();
        let mut logits = session.prefill(&batch).unwrap();
        let mut rng = Pcg::new(r.sampling.seed, SAMPLE_STREAM);
        let s = &r.sampling;
        let first = sample_logits(logits.row(0), s.temperature, s.top_k, &mut rng) as i32;
        ttft_ms.push(ta.elapsed().as_secs_f64() * 1000.0);
        let mut tokens = vec![first];
        while tokens.len() < r.max_new_tokens {
            let t1 = Instant::now();
            logits = session.decode(&[*tokens.last().unwrap()]).unwrap();
            lat_ms.push(t1.elapsed().as_secs_f64() * 1000.0);
            tokens.push(sample_logits(logits.row(0), s.temperature, s.top_k, &mut rng) as i32);
        }
        total_tokens += tokens.len();
        token_streams.push(tokens);
    }
    RunResult { token_streams, total_tokens, secs: t0.elapsed().as_secs_f64(), lat_ms, ttft_ms }
}

/// The continuous-batching path: all requests through the scheduler,
/// submission throttled by the bounded queue (`serve::load::drive`).
/// Also returns the shared KV pool's counters, for the paged-vs-ring
/// memory comparison.
fn run_batched(
    engine: &NativeEngine,
    reqs: &[GenRequest],
    slots: usize,
) -> (RunResult, PoolStats, ServeStats, ServeHists) {
    let opts = ServeOpts { slots, queue_cap: reqs.len().max(1), ..ServeOpts::default() };
    let mut sched = Scheduler::new(engine, &opts).unwrap();
    let t0 = Instant::now();
    let mut lat_ms = Vec::new();
    drive(&mut sched, reqs.to_vec(), |report| {
        // Every token sampled this tick waited one fused step (which
        // may include co-resident prefill chunks — that interference
        // is exactly what `prefill_chunk` bounds).
        for _ in 0..report.tokens {
            lat_ms.push(report.decode_seconds * 1000.0);
        }
    })
    .unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let pool = sched.pool_stats();
    let stats = sched.stats().clone();
    let hists = sched.hists().clone();
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    let total_tokens = stats.total_tokens as usize;
    let ttft_ms: Vec<f64> = outs.iter().filter_map(|o| o.ttft_s.map(|t| t * 1000.0)).collect();
    let result = RunResult {
        token_streams: outs.into_iter().map(|o| o.tokens).collect(),
        total_tokens,
        secs,
        lat_ms,
        ttft_ms,
    };
    (result, pool, stats, hists)
}

/// Observability scenario: the same traffic with both sinks on (JSONL
/// metrics + Chrome trace under `target/`) and the global MoE routing
/// collector enabled. Asserts the zero-behavior-change contract —
/// token streams bit-identical to the obs-off batched run, histogram
/// counts reconciling exactly with [`ServeStats`] — and measures the
/// sink's per-tick overhead against the obs-off run (the two runs tick
/// the same deterministic schedule, so per-tick means are comparable).
fn run_obs(
    engine: &NativeEngine,
    name: &str,
    reqs: &[GenRequest],
    slots: usize,
    plain: &RunResult,
    plain_hists: &ServeHists,
) -> Json {
    let _ = std::fs::create_dir_all("target");
    let metrics_path = format!("target/obs_{name}_metrics.jsonl");
    let trace_path = format!("target/obs_{name}_trace.json");
    let opts = ServeOpts {
        slots,
        queue_cap: reqs.len().max(1),
        obs: ObsOpts { metrics: Some(metrics_path.clone()), trace: Some(trace_path.clone()) },
        ..ServeOpts::default()
    };
    routing::reset();
    routing::set_enabled(true);
    let mut sched = Scheduler::new(engine, &opts).unwrap();
    drive(&mut sched, reqs.to_vec(), |_r| {}).unwrap();
    routing::set_enabled(false);
    let rt = routing::snapshot();
    let st = sched.stats().clone();
    let h = sched.hists().clone();
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    let streams: Vec<Vec<i32>> = outs.into_iter().map(|o| o.tokens).collect();
    assert_eq!(plain.token_streams, streams, "obs-on streams diverged from obs-off");
    assert_eq!(
        h.ttft_s.count(),
        st.finished + st.errors,
        "obs: ttft histogram count != finished + errors"
    );
    assert_eq!(h.itl_s.count(), st.total_tokens, "obs: itl histogram count != total tokens");

    let off = plain_hists.tick_s.mean();
    let on = h.tick_s.mean();
    let overhead_pct = if off > 0.0 { (on / off - 1.0) * 100.0 } else { 0.0 };

    // Routing balance: per-layer selection counts aggregated over the
    // four MoE projections — the worst layer's entropy and hottest
    // expert share summarize how balanced routing stayed.
    let n_layers = rt.selections.keys().map(|&(l, _)| l + 1).max().unwrap_or(0);
    let mut entropy_min = 1.0f64;
    let mut share_max = 0.0f64;
    for layer in 0..n_layers {
        let mut counts: Vec<u64> = Vec::new();
        for proj in 0..routing::PROJ_NAMES.len() {
            if let Some(c) = rt.selections.get(&(layer, proj)) {
                if counts.len() < c.len() {
                    counts.resize(c.len(), 0);
                }
                for (acc, &n) in counts.iter_mut().zip(c) {
                    *acc += n;
                }
            }
        }
        entropy_min = entropy_min.min(normalized_entropy(&counts));
        share_max = share_max.max(max_share(&counts));
    }
    let metrics_records = std::fs::read_to_string(&metrics_path)
        .map(|t| t.lines().filter(|l| !l.trim().is_empty()).count())
        .unwrap_or(0);
    let trace_events = Json::parse_file(&trace_path)
        .ok()
        .and_then(|d| d.get("traceEvents").map(|e| e.as_arr().map_or(0, <[Json]>::len)))
        .unwrap_or(0);
    assert!(metrics_records > 0, "obs run emitted no metrics records");
    assert!(trace_events > 0, "obs run emitted no trace events");
    println!(
        "obs: sink overhead {overhead_pct:+.1}%/tick \
         ({metrics_records} metrics records, {trace_events} trace events); \
         routing entropy >= {entropy_min:.3}, max expert share <= {share_max:.2}, \
         fused union {:.0}% of slots",
        100.0 * rt.mean_union_frac(),
    );
    Json::from_pairs(vec![
        ("obs_overhead_pct", num(overhead_pct)),
        ("tick_mean_off_ms", num(off * 1e3)),
        ("tick_mean_on_ms", num(on * 1e3)),
        ("metrics_records", num(metrics_records as f64)),
        ("trace_events", num(trace_events as f64)),
        ("routing_entropy_min", num(entropy_min)),
        ("routing_max_share", num(share_max)),
        ("union_mean_experts", num(rt.mean_union())),
        ("union_frac", num(rt.mean_union_frac())),
    ])
}

/// Draft-and-verify speculative scenario: the same traffic through
/// [`Scheduler::with_draft`] with the stock 1-layer draft model
/// (`configs/tiny-sh-draft.json`). Streams are asserted identical to
/// the serial oracle — the sample-and-match accept walk is exact — so
/// the only thing speculation may change is cost per emitted token.
/// Returns the table row's RunResult plus a JSON blob with the
/// acceptance rate, the per-phase time split, the scheduler-overhead
/// op tally, and the measured break-even acceptance. `None` when the
/// draft config is missing or incompatible with this target (the
/// shared-pool contract needs equal vocab and d_head).
fn run_spec(
    engine: &NativeEngine,
    cfg: &ModelConfig,
    reqs: &[GenRequest],
    slots: usize,
    serial: &RunResult,
    plain: &ServeStats,
) -> Option<(RunResult, Json)> {
    let draft_cfg = match ModelConfig::load("configs/tiny-sh-draft.json") {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP spec scenario: {e:#}");
            return None;
        }
    };
    if draft_cfg.vocab_size != cfg.vocab_size || draft_cfg.d_head != cfg.d_head {
        return None;
    }
    let draft = NativeEngine::new(&draft_cfg, 43).unwrap();
    let opts = ServeOpts { slots, queue_cap: reqs.len().max(1), ..ServeOpts::default() };
    let mut sched = Scheduler::with_draft(engine, &draft, &opts).unwrap();
    let k = sched.spec_k();
    let t0 = Instant::now();
    let mut lat_ms = Vec::new();
    drive(&mut sched, reqs.to_vec(), |report| {
        for _ in 0..report.tokens {
            lat_ms.push(report.decode_seconds * 1000.0);
        }
    })
    .unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let overhead_ops = sched.overhead_macs().scheduler_overhead;
    let st = sched.stats().clone();
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    let ttft_ms: Vec<f64> = outs.iter().filter_map(|o| o.ttft_s.map(|t| t * 1000.0)).collect();
    let streams: Vec<Vec<i32>> = outs.into_iter().map(|o| o.tokens).collect();
    assert_eq!(
        serial.token_streams, streams,
        "speculative decode diverged from the serial loop"
    );
    // Break-even: one verify cycle costs draft + verify wall time and
    // emits `1 + acceptance * k` tokens where a plain step emits one —
    // speculation pays off when acceptance exceeds
    // ((cycle / plain_step) - 1) / k. Both sides are whole-run
    // per-token averages (prefill work included in both), so this is
    // an aggregate estimate, not a per-tick microbenchmark.
    let cycles = (st.drafted as f64 / k.max(1) as f64).max(1.0);
    let cycle_s = (st.draft_seconds + st.step_seconds) / cycles;
    let plain_step_s = plain.step_seconds / plain.decode_tokens.max(1) as f64;
    let breakeven = (cycle_s / plain_step_s.max(1e-12) - 1.0) / k.max(1) as f64;
    let total_tokens = st.total_tokens as usize;
    let json = Json::from_pairs(vec![
        ("spec_k", num(k as f64)),
        ("drafted", num(st.drafted as f64)),
        ("accepted", num(st.accepted as f64)),
        ("acceptance_rate", num(st.acceptance_rate())),
        ("breakeven_acceptance", num(breakeven)),
        ("spec_tok_s", num(total_tokens as f64 / secs.max(1e-9))),
        ("draft_seconds", num(st.draft_seconds)),
        ("step_seconds", num(st.step_seconds)),
        ("overhead_seconds", num(st.overhead_seconds)),
        ("scheduler_overhead", num(overhead_ops)),
    ]);
    Some((RunResult { token_streams: streams, total_tokens, secs, lat_ms, ttft_ms }, json))
}

/// Chaos scenario: the same traffic under a fixed seeded fault plan
/// with the per-tick invariant auditor on. Reports goodput (tokens
/// from requests that finished clean), the fault/error/recovery
/// counts, and breaker trips — and asserts the robustness contract on
/// the bench path too: surviving streams bit-identical to the serial
/// oracle, `faults_injected == errors + retries_recovered`, auditor
/// green every tick.
fn run_chaos(engine: &NativeEngine, reqs: &[GenRequest], slots: usize, serial: &RunResult) -> Json {
    let plan = FaultPlan::random(0xFA17, 6, 64, reqs.len() as u64);
    let opts = ServeOpts {
        slots,
        queue_cap: reqs.len().max(1),
        audit: true,
        faults: Some(plan),
        ..ServeOpts::default()
    };
    let mut sched = Scheduler::new(engine, &opts).unwrap();
    let t0 = Instant::now();
    drive(&mut sched, reqs.to_vec(), |_r| {}).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let st = sched.stats().clone();
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    let mut good_tokens = 0usize;
    let mut errored = 0usize;
    for o in &outs {
        match o.finish {
            FinishReason::Length => {
                assert_eq!(
                    o.tokens, serial.token_streams[o.id as usize],
                    "chaos: surviving request {} diverged from the serial oracle",
                    o.id
                );
                good_tokens += o.tokens.len();
            }
            FinishReason::Error => {
                assert!(o.error.is_some(), "chaos: error output without a reason");
                errored += 1;
            }
            other => panic!("chaos: unexpected finish {other:?}"),
        }
    }
    assert_eq!(
        st.faults_injected,
        st.errors + st.retries_recovered,
        "chaos: fault accounting identity broken"
    );
    assert_eq!(st.audit_ticks, st.ticks, "chaos: auditor skipped a tick");
    println!(
        "chaos: {} fault(s) injected, {} request(s) errored, {} recovered, \
         {:.0} goodput tok/s over {} audited tick(s)",
        st.faults_injected,
        errored,
        st.retries_recovered,
        good_tokens as f64 / secs.max(1e-9),
        st.audit_ticks,
    );
    Json::from_pairs(vec![
        ("faults_injected", num(st.faults_injected as f64)),
        ("errors", num(st.errors as f64)),
        ("retries_recovered", num(st.retries_recovered as f64)),
        ("spec_trips", num(st.spec_trips as f64)),
        ("audit_ticks", num(st.audit_ticks as f64)),
        ("errored_requests", num(errored as f64)),
        ("error_rate", num(errored as f64 / outs.len().max(1) as f64)),
        ("goodput_tok_s", num(good_tokens as f64 / secs.max(1e-9))),
    ])
}

/// Head-of-line scenario: short decoding requests co-resident with one
/// ctx-length prompt arriving mid-flight, at a given `prefill_chunk`.
/// Returns (max per-tick prefill positions, co-resident ITL p99 ms,
/// co-resident max ITL ms) where "co-resident" means ticks that
/// sampled at least one token (the short requests' experience).
fn run_hol(engine: &NativeEngine, cfg: &ModelConfig, chunk: usize) -> (usize, f64, f64) {
    let ctx = cfg.ctx_len();
    let sampling = SamplingParams { temperature: 0.0, top_k: 0, seed: 11, eos_token: None };
    // Three short prompts decoding long enough to overlap the long
    // prompt's whole prefill, plus the stressor: a full-window prompt.
    let mut reqs = synth_requests(cfg, 3, 2, ctx.max(16), &sampling);
    let long = synth_requests(cfg, 1, 1, 4, &sampling).remove(0);
    let long_prompt: Vec<i32> = (0..ctx).map(|i| (i % cfg.vocab_size) as i32).collect();
    let long = GenRequest { prompt: long_prompt, ..long };

    let opts = ServeOpts { slots: 4, queue_cap: 8, prefill_chunk: chunk, ..ServeOpts::default() };
    let mut sched = Scheduler::new(engine, &opts).unwrap();
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    reqs.push(long.clone());
    let mut max_prefill = 0usize;
    let mut itl = Vec::new();
    let mut track = |r: &switchhead::serve::TickReport| {
        max_prefill = max_prefill.max(r.prefill_positions);
        if r.tokens > 0 {
            itl.push(r.decode_seconds * 1000.0);
        }
    };
    // Let the shorts start decoding, then drop the long prompt in.
    for _ in 0..3 {
        track(&sched.tick().unwrap());
    }
    sched.submit(long).unwrap();
    let mut guard = 0;
    while !sched.is_idle() {
        track(&sched.tick().unwrap());
        guard += 1;
        assert!(guard < 100_000, "HOL scenario did not drain");
    }
    // The tentpole's structural claim: per-tick prefill work is
    // bounded by the chunk size, however long the prompt.
    assert!(
        max_prefill <= chunk,
        "per-tick prefill positions {max_prefill} exceeded prefill_chunk {chunk}"
    );
    // Chunking must not change any stream: compare against the serial
    // oracle for all four requests.
    let serial = run_serial(engine, &reqs);
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    let streams: Vec<Vec<i32>> = outs.into_iter().map(|o| o.tokens).collect();
    assert_eq!(serial.token_streams, streams, "HOL chunked streams diverged from serial");
    (max_prefill, quantile(&itl, 0.99), itl.iter().cloned().fold(0.0f64, f64::max))
}

/// Int8 scenario: the same traffic on a second engine built with
/// `Precision::Int8` (quantized expert weight banks + int8 KV pages,
/// f32 accumulation). Greedy streams may legitimately flip near-tie
/// tokens — the logit tolerance band and argmax-agreement contracts
/// live in `rust/tests/quant.rs` — so the serving assertions here are
/// the precision-invariant ones: same request set finishing by budget
/// with the same token counts, the same page high-water (admission is
/// position-denominated), and the headline memory claim: bytes per
/// session (weights + peak KV, amortized over slots) under half of
/// f32.
fn run_quant(
    cfg: &ModelConfig,
    reqs: &[GenRequest],
    slots: usize,
    f32_engine: &NativeEngine,
    f32_pool: &PoolStats,
    plain: &RunResult,
) -> Json {
    let mut qcfg = cfg.clone();
    qcfg.precision = switchhead::config::Precision::Int8;
    let qengine = NativeEngine::new(&qcfg, 42).unwrap();
    assert!(qengine.model.quant.is_some(), "int8 engine lacks a quantized bank");
    let opts = ServeOpts {
        slots,
        queue_cap: reqs.len().max(1),
        precision: qcfg.precision,
        ..ServeOpts::default()
    };
    let mut sched = Scheduler::new(&qengine, &opts).unwrap();
    let t0 = Instant::now();
    drive(&mut sched, reqs.to_vec(), |_r| {}).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let pool = sched.pool_stats();
    let st = sched.stats().clone();
    let mut outs = sched.drain_finished();
    outs.sort_by_key(|o| o.id);
    assert_eq!(outs.len(), reqs.len(), "int8 serve dropped requests");
    for o in &outs {
        assert!(
            matches!(o.finish, FinishReason::Length),
            "int8 request {} finished {:?}, expected Length",
            o.id,
            o.finish
        );
        assert_eq!(
            o.tokens.len(),
            plain.token_streams[o.id as usize].len(),
            "int8 request {} token count diverged from f32",
            o.id
        );
    }
    assert_eq!(
        pool.high_water, f32_pool.high_water,
        "paged admission must be precision-invariant (position-denominated)"
    );

    let weights_f32 = f32_engine.model.weight_bytes();
    let weights_int8 = qengine.model.weight_bytes();
    let bytes_f32 = (weights_f32 + f32_pool.peak_bytes()) as f64 / slots as f64;
    let bytes_int8 = (weights_int8 + pool.peak_bytes()) as f64 / slots as f64;
    let ratio = bytes_int8 / bytes_f32.max(1e-9);
    assert!(
        2.0 * bytes_int8 < bytes_f32,
        "int8 bytes/session {bytes_int8:.0} not under half of f32 {bytes_f32:.0}"
    );
    let tok_s = st.total_tokens as f64 / secs.max(1e-9);
    println!(
        "quant: int8 {tok_s:.0} tok/s, {bytes_int8:.0} bytes/session vs {bytes_f32:.0} f32 \
         ({:.0}%); KV peak {} vs {} bytes at equal page high-water {}",
        100.0 * ratio,
        pool.peak_bytes(),
        f32_pool.peak_bytes(),
        pool.high_water,
    );
    Json::from_pairs(vec![
        ("quant_tok_s", num(tok_s)),
        ("bytes_per_session", num(bytes_int8)),
        ("bytes_per_session_f32", num(bytes_f32)),
        ("bytes_ratio", num(ratio)),
        ("bytes_ratio_lt_half", Json::Bool(2.0 * bytes_int8 < bytes_f32)),
        ("weight_bytes_int8", num(weights_int8 as f64)),
        ("weight_bytes_f32", num(weights_f32 as f64)),
        ("kv_peak_bytes_int8", num(pool.peak_bytes() as f64)),
        ("kv_peak_bytes_f32", num(f32_pool.peak_bytes() as f64)),
    ])
}

fn bench_one(
    name: &str,
    requests: usize,
    slots: usize,
    tokens: usize,
    table: &mut Table,
) -> Option<Json> {
    let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP {name}: {e:#}");
            return None;
        }
    };
    if cfg.task != Task::Lm {
        return None;
    }
    let engine = NativeEngine::new(&cfg, 42).unwrap();
    let sampling = SamplingParams { temperature: 0.0, top_k: 0, seed: 5, eos_token: None };
    let reqs = synth_requests(&cfg, requests, (cfg.seq_len / 2).max(1), tokens, &sampling);

    let serial = run_serial(&engine, &reqs);
    let (batched, pool, batched_stats, batched_hists) = run_batched(&engine, &reqs, slots);
    assert_eq!(
        serial.token_streams, batched.token_streams,
        "{name}: batched decode diverged from the serial loop"
    );

    // Observability: same traffic with sinks + routing telemetry on —
    // asserts zero behavior change, measures the sink overhead.
    let obs = run_obs(&engine, name, &reqs, slots, &batched, &batched_hists);

    // Speculative decoding: same traffic, draft-and-verify scheduler.
    let spec = run_spec(&engine, &cfg, &reqs, slots, &serial, &batched_stats);

    // Chaos: same traffic again, now under a seeded fault plan with
    // the per-tick auditor on — measures goodput under injected faults.
    let chaos = run_chaos(&engine, &reqs, slots, &serial);

    // Quantization: same traffic on an int8 engine + int8 KV pool —
    // asserts the >=2x bytes/session reduction and position-invariant
    // admission, reports the memory split.
    let quant = run_quant(&cfg, &reqs, slots, &engine, &pool, &batched);

    // Head-of-line interference: a ctx-length prompt next to short
    // decoders, chunked (bounded per-tick prefill) vs monolithic
    // (whole prompt in one tick).
    let ctx = cfg.ctx_len();
    let chunk = (ctx / 4).max(1);
    let (hol_chunk_prefill, hol_chunk_p99, hol_chunk_max) = run_hol(&engine, &cfg, chunk);
    let (hol_mono_prefill, hol_mono_p99, hol_mono_max) = run_hol(&engine, &cfg, ctx);

    // Memory: what the paged pool actually peaked at, vs what `slots`
    // preallocated full rings (the pre-paging design) would pin
    // regardless of traffic: 2 (K+V) * ctx_len * d_head floats per
    // (session, layer, stream).
    let paged_peak_kv_floats = pool.peak_floats();
    let ring_kv_floats = slots * cfg.n_layers * cfg.kv_streams() * 2 * cfg.ctx_len() * cfg.d_head;
    let kv_ratio = paged_peak_kv_floats as f64 / ring_kv_floats as f64;
    println!(
        "{name}: peak paged KV {} floats vs {} ring-preallocated ({:.0}%); \
         HOL max prefill/tick {} (chunk {}) vs {} (monolithic)",
        paged_peak_kv_floats,
        ring_kv_floats,
        100.0 * kv_ratio,
        hol_chunk_prefill,
        chunk,
        hol_mono_prefill,
    );

    let serial_tok_s = serial.total_tokens as f64 / serial.secs.max(1e-9);
    let batched_tok_s = batched.total_tokens as f64 / batched.secs.max(1e-9);
    let speedup = batched_tok_s / serial_tok_s.max(1e-9);
    let row = |mode: &str, r: &RunResult, tok_s: f64| {
        vec![
            name.into(),
            mode.into(),
            format!("{:.0}", tok_s),
            format!("{:.3}", quantile(&r.lat_ms, 0.5)),
            format!("{:.3}", quantile(&r.lat_ms, 0.99)),
            format!("{:.3}", quantile(&r.ttft_ms, 0.5)),
            format!("{:.3}", quantile(&r.ttft_ms, 0.99)),
            format!("{}", r.total_tokens),
        ]
    };
    table.push(row("serial", &serial, serial_tok_s));
    table.push(row("batched", &batched, batched_tok_s));
    if let Some((r, _)) = &spec {
        table.push(row("spec", r, r.total_tokens as f64 / r.secs.max(1e-9)));
    }
    let mut pairs = vec![
        ("config", str_(name)),
        ("requests", num(requests as f64)),
        ("slots", num(slots as f64)),
        ("tokens_per_request", num(tokens as f64)),
        ("serial_tok_s", num(serial_tok_s)),
        ("batched_tok_s", num(batched_tok_s)),
        ("speedup", num(speedup)),
        ("serial_p50_ms", num(quantile(&serial.lat_ms, 0.5))),
        ("serial_p95_ms", num(quantile(&serial.lat_ms, 0.95))),
        ("serial_itl_p99_ms", num(quantile(&serial.lat_ms, 0.99))),
        ("batched_p50_ms", num(quantile(&batched.lat_ms, 0.5))),
        ("batched_p95_ms", num(quantile(&batched.lat_ms, 0.95))),
        ("batched_itl_p99_ms", num(quantile(&batched.lat_ms, 0.99))),
        ("serial_ttft_p50_ms", num(quantile(&serial.ttft_ms, 0.5))),
        ("serial_ttft_p95_ms", num(quantile(&serial.ttft_ms, 0.95))),
        ("serial_ttft_p99_ms", num(quantile(&serial.ttft_ms, 0.99))),
        ("batched_ttft_p50_ms", num(quantile(&batched.ttft_ms, 0.5))),
        ("batched_ttft_p95_ms", num(quantile(&batched.ttft_ms, 0.95))),
        ("batched_ttft_p99_ms", num(quantile(&batched.ttft_ms, 0.99))),
        (
            "hol",
            Json::from_pairs(vec![
                ("long_prompt_len", num(ctx as f64)),
                ("prefill_chunk", num(chunk as f64)),
                ("chunked_max_prefill_positions", num(hol_chunk_prefill as f64)),
                ("chunked_itl_p99_ms", num(hol_chunk_p99)),
                ("chunked_max_itl_ms", num(hol_chunk_max)),
                ("mono_max_prefill_positions", num(hol_mono_prefill as f64)),
                ("mono_itl_p99_ms", num(hol_mono_p99)),
                ("mono_max_itl_ms", num(hol_mono_max)),
            ]),
        ),
        ("total_tokens", num(batched.total_tokens as f64)),
        ("paged_peak_kv_floats", num(paged_peak_kv_floats as f64)),
        ("ring_kv_floats", num(ring_kv_floats as f64)),
        ("paged_over_ring_kv", num(kv_ratio)),
    ];
    pairs.push(("chaos", chaos));
    pairs.push(("obs", obs));
    pairs.push(("quant", quant));
    if let Some((_, sj)) = spec {
        pairs.push(("spec", sj));
    }
    Some(Json::from_pairs(pairs))
}

fn main() {
    let smoke = std::env::var("SWITCHHEAD_BENCH_SMOKE").as_deref() == Ok("1");
    // Acceptance shape: 8 concurrent sessions vs the serial loop.
    // Smoke: 4 concurrent tiny-sh requests (make check, 1 thread).
    let (requests, slots, tokens) = if smoke { (4, 4, 8) } else { (8, 8, 32) };
    let configs: &[&str] =
        if smoke { &["tiny-sh"] } else { &["tiny-sh", "tiny-dense", "tiny-switchall"] };

    let mut table = Table::new(
        &format!(
            "Serve throughput ({} concurrent requests, {} slots, {} tok/request, {} threads)",
            requests,
            slots,
            tokens,
            kernels::threads()
        ),
        &[
            "config",
            "mode",
            "tok/s",
            "p50 ms/tok",
            "p99 ms/tok",
            "ttft p50 ms",
            "ttft p99 ms",
            "tokens",
        ],
    );
    let mut rows = Vec::new();
    for name in configs {
        if let Some(j) = bench_one(name, requests, slots, tokens, &mut table) {
            rows.push(j);
        }
    }
    table.print();

    let out = Json::from_pairs(vec![
        ("bench", str_("serve_throughput")),
        ("smoke", Json::Bool(smoke)),
        ("requests", num(requests as f64)),
        ("slots", num(slots as f64)),
        ("tokens_per_request", num(tokens as f64)),
        ("threads", num(kernels::threads() as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let text = out.to_string_pretty() + "\n";
    if smoke {
        // The smoke run is the CI gate for the latency schema: the
        // TTFT/ITL percentile fields must exist in the emitted JSON.
        for key in [
            "serial_ttft_p50_ms",
            "serial_ttft_p99_ms",
            "batched_ttft_p50_ms",
            "batched_ttft_p95_ms",
            "batched_ttft_p99_ms",
            "batched_itl_p99_ms",
            "chunked_max_prefill_positions",
            "acceptance_rate",
            "breakeven_acceptance",
            "scheduler_overhead",
            "faults_injected",
            "retries_recovered",
            "goodput_tok_s",
            "obs_overhead_pct",
            "routing_entropy_min",
            "metrics_records",
            "union_frac",
            "quant_tok_s",
            "bytes_per_session",
            "bytes_per_session_f32",
            "bytes_ratio_lt_half",
        ] {
            assert!(text.contains(key), "smoke JSON is missing the `{key}` field");
        }
    }
    // Smoke runs land under target/ (gitignored) so `make check` never
    // clobbers a real `make bench-serve` trajectory file.
    let path = if smoke {
        "target/BENCH_serve_throughput.smoke.json"
    } else {
        "BENCH_serve_throughput.json"
    };
    match std::fs::write(path, text) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN: could not write {path}: {e}"),
    }
}
