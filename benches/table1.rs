//! cargo-bench driver for paper Table 1 (see rust/src/bench/tables.rs).
//! SWITCHHEAD_BENCH_QUICK=1 skips the measured tiny-scale training rows;
//! SWITCHHEAD_BENCH_STEPS controls their length (default 120).
use std::path::Path;

fn main() {
    let quick = std::env::var("SWITCHHEAD_BENCH_QUICK").is_ok();
    let steps: usize = std::env::var("SWITCHHEAD_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    match switchhead::bench::tables::table1(Path::new("artifacts"), quick, steps) {
        Ok(out) => println!("{out}"),
        Err(e) => println!("SKIP table1: {e:#}"),
    }
}
