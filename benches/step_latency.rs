//! Micro-benchmark: per-entry execution latency (train_step / eval_step
//! / score) for the parameter-matched tiny family, plus the
//! decode-throughput table that measures the Session API's incremental
//! decoding against full-window recompute. This is the L3 §Perf
//! instrument — it separates coordinator overhead (upload + readback)
//! from device execute time. See EXPERIMENTS.md §Perf.
//!
//! Smoke mode: when a config's PJRT artifacts are absent (clean
//! checkout, no Python), the native backend is timed instead —
//! `score` and `next_logits` on host buffers — so `make smoke` always
//! produces latency rows. Set SWITCHHEAD_BENCH_NATIVE=0 to disable the
//! fallback. The decode table always runs on the native backend (the
//! incremental KV-cache path only exists there).
use std::path::Path;

use switchhead::bench::{fmt_si, time, Table};
use switchhead::config::{ModelConfig, Task};
use switchhead::model::NativeEngine;
use switchhead::runtime::{Backend, Engine, Session, TokenBatch};
use switchhead::util::rng::Pcg;

/// Native-backend smoke rows (artifact-free).
fn bench_native(cfg: &ModelConfig, name: &str, iters: usize) {
    let engine = match NativeEngine::new(cfg, 42) {
        Ok(e) => e,
        Err(e) => return println!("SKIP {name} (native): {e:#}"),
    };
    let mut rng = Pcg::new(1, 1);
    match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            let batch = TokenBatch::new(tok.clone(), cfg.batch_size, t1).unwrap();
            let r = time(&format!("{name}/native score"), 1, iters, || {
                let _ = engine.score(&batch).unwrap();
            });
            println!("{}", r.row());
            let tok2: Vec<i32> = tok[..cfg.batch_size * cfg.seq_len].to_vec();
            let batch2 = TokenBatch::new(tok2, cfg.batch_size, cfg.seq_len).unwrap();
            let r = time(&format!("{name}/native next_logits"), 1, iters, || {
                let _ = engine.next_logits(&batch2).unwrap();
            });
            println!("{}", r.row());
        }
        Task::ListOps => {
            let (tok, _lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            let batch = TokenBatch::new(tok, cfg.batch_size, cfg.seq_len).unwrap();
            let r = time(&format!("{name}/native class_logits"), 1, iters, || {
                let _ = engine.class_logits(&batch).unwrap();
            });
            println!("{}", r.row());
        }
    }
}

fn bench_config(name: &str, iters: usize) {
    let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
        Ok(c) => c,
        Err(e) => return println!("SKIP {name}: {e:#}"),
    };
    let dir = Path::new("artifacts").join(&cfg.name);
    if !dir.join("manifest.json").exists() {
        if std::env::var("SWITCHHEAD_BENCH_NATIVE").as_deref() == Ok("0") {
            return println!("SKIP {name}: artifacts not built");
        }
        return bench_native(&cfg, name, iters.min(10));
    }
    let engine =
        Engine::load(&dir, Some(&["init", "train_step", "eval_step", "score", "metrics"]))
            .unwrap();
    let mut rng = Pcg::new(1, 1);
    let mut flat = engine.init(1).unwrap();

    let (bufs, _dims): (Vec<_>, Vec<Vec<usize>>) = match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            (
                vec![engine.upload_i32(&tok, &[cfg.batch_size, t1]).unwrap()],
                vec![vec![cfg.batch_size, t1]],
            )
        }
        Task::ListOps => {
            let (tok, lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            (
                vec![
                    engine.upload_i32(&tok, &[cfg.batch_size, cfg.seq_len]).unwrap(),
                    engine.upload_i32(&lab, &[cfg.batch_size]).unwrap(),
                ],
                vec![],
            )
        }
    };
    let refs: Vec<&_> = bufs.iter().collect();

    let mut step = 0;
    let r = time(&format!("{name}/train_step"), 3, iters, || {
        let (next, _) = engine.train_step(&flat, step, &refs, None).unwrap();
        flat = next;
        step += 1;
    });
    println!("{}", r.row());
    let r = time(&format!("{name}/eval_step"), 3, iters, || {
        let _ = engine.eval_step(&flat, &refs).unwrap();
    });
    println!("{}", r.row());
    if cfg.task == Task::Lm && engine.manifest.entries.contains_key("score") {
        let r = time(&format!("{name}/score"), 3, iters, || {
            let _ = engine.score(&flat, &bufs[0]).unwrap();
        });
        println!("{}", r.row());
    }
}

/// Decode-throughput table: per config, wall-clock and MAC cost of the
/// Session prefill/decode path vs. the legacy full-window recompute —
/// the measurable form of the paper's per-token inference claim.
fn bench_decode(names: &[&str], iters: usize) {
    let mut table = Table::new(
        "Session decode throughput (native backend, tokens/sec per batch row)",
        &[
            "config",
            "prefill ms",
            "decode ms/tok",
            "recompute ms/tok",
            "speedup",
            "decode tok/s",
            "MACs/tok decode",
            "MACs/tok recompute",
        ],
    );
    for name in names {
        let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
            Ok(c) => c,
            Err(e) => {
                println!("SKIP {name}: {e:#}");
                continue;
            }
        };
        if cfg.task != Task::Lm {
            continue;
        }
        let engine = NativeEngine::new(&cfg, 42).unwrap();
        let mut rng = Pcg::new(2, 2);
        let b = cfg.batch_size;
        let t = cfg.seq_len;
        let prompt: Vec<i32> = (0..b * (t / 2)).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let prompt = TokenBatch::new(prompt, b, t / 2).unwrap();

        // Prefill latency (fresh session each iteration).
        let r_prefill = time(&format!("{name}/prefill"), 1, iters.min(10), || {
            let mut s = engine.open_session(b).unwrap();
            let _ = s.prefill(&prompt).unwrap();
        });

        // Steady-state decode: one long-lived session, time per token,
        // and capture the per-token MAC delta from the session counter.
        let mut session = engine.open_session(b).unwrap();
        let mut logits = session.prefill(&prompt).unwrap();
        let macs_before = session.macs().unwrap().total();
        let mut steps = 0u64;
        let r_decode = time(&format!("{name}/decode"), 2, iters, || {
            let next: Vec<i32> = (0..b)
                .map(|row| {
                    let l = logits.row(row);
                    l.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i as i32)
                        .unwrap()
                })
                .collect();
            logits = session.decode(&next).unwrap();
            steps += 1;
        });
        let decode_macs_tok =
            (session.macs().unwrap().total() - macs_before) / steps as f64 / b as f64;

        // Legacy full-window recompute per token.
        let window: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let window = TokenBatch::new(window, b, t).unwrap();
        let r_full = time(&format!("{name}/recompute"), 1, iters.min(10), || {
            let _ = engine.next_logits(&window).unwrap();
        });
        let full_macs_tok = engine.count_macs().unwrap().total();

        table.push(vec![
            (*name).into(),
            format!("{:.3}", r_prefill.mean_ms),
            format!("{:.3}", r_decode.mean_ms),
            format!("{:.3}", r_full.mean_ms),
            format!("{:.1}x", r_full.mean_ms / r_decode.mean_ms.max(1e-9)),
            format!("{:.0}", 1000.0 / r_decode.mean_ms.max(1e-9)),
            fmt_si(decode_macs_tok),
            fmt_si(full_macs_tok),
        ]);
    }
    table.print();
}

fn main() {
    let iters: usize = std::env::var("SWITCHHEAD_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    for name in ["tiny-dense", "tiny-sh", "tiny-moa", "tiny-switchall"] {
        bench_config(name, iters);
    }
    bench_decode(&["tiny-dense", "tiny-sh", "tiny-rope-sh", "tiny-switchall"], iters);
}
