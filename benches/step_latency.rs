//! Micro-benchmark: per-entry execution latency (train_step / eval_step
//! / score) for the parameter-matched tiny family, plus the
//! decode-throughput table that measures the Session API's incremental
//! decoding against full-window recompute. This is the L3 §Perf
//! instrument — it separates coordinator overhead (upload + readback)
//! from device execute time. See EXPERIMENTS.md §Perf.
//!
//! Smoke mode: when a config's PJRT artifacts are absent (clean
//! checkout, no Python), the native backend is timed instead —
//! `score` and `next_logits` on host buffers — so `make smoke` always
//! produces latency rows. Set SWITCHHEAD_BENCH_NATIVE=0 to disable the
//! fallback. The decode table always runs on the native backend (the
//! incremental KV-cache path only exists there).
//!
//! Since the kernels PR the harness also measures the parallel compute
//! layer: a thread-scaling table (prefill / decode at 1, 2, 4 threads
//! via `kernels::set_threads`) and a kernel-level microbench (dense vs
//! expert-grouped MoE matmul GFLOP/s), and every run emits
//! `BENCH_step_latency.json` so the perf trajectory is diffable across
//! PRs. `SWITCHHEAD_BENCH_SMOKE=1` shrinks everything to a 1-thread
//! sanity pass (wired into `make check`).
use std::path::Path;

use switchhead::bench::{fmt_si, time, Table};
use switchhead::config::{ModelConfig, Task};
use switchhead::kernels;
use switchhead::model::NativeEngine;
use switchhead::runtime::{Backend, Engine, Session, TokenBatch};
use switchhead::util::json::Json;
use switchhead::util::rng::Pcg;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn str_(s: &str) -> Json {
    Json::Str(s.to_string())
}

/// Native-backend smoke rows (artifact-free).
fn bench_native(cfg: &ModelConfig, name: &str, iters: usize) {
    let engine = match NativeEngine::new(cfg, 42) {
        Ok(e) => e,
        Err(e) => return println!("SKIP {name} (native): {e:#}"),
    };
    let mut rng = Pcg::new(1, 1);
    match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            let batch = TokenBatch::new(tok.clone(), cfg.batch_size, t1).unwrap();
            let r = time(&format!("{name}/native score"), 1, iters, || {
                let _ = engine.score(&batch).unwrap();
            });
            println!("{}", r.row());
            let tok2: Vec<i32> = tok[..cfg.batch_size * cfg.seq_len].to_vec();
            let batch2 = TokenBatch::new(tok2, cfg.batch_size, cfg.seq_len).unwrap();
            let r = time(&format!("{name}/native next_logits"), 1, iters, || {
                let _ = engine.next_logits(&batch2).unwrap();
            });
            println!("{}", r.row());
        }
        Task::ListOps => {
            let (tok, _lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            let batch = TokenBatch::new(tok, cfg.batch_size, cfg.seq_len).unwrap();
            let r = time(&format!("{name}/native class_logits"), 1, iters, || {
                let _ = engine.class_logits(&batch).unwrap();
            });
            println!("{}", r.row());
        }
    }
}

fn bench_config(name: &str, iters: usize) {
    let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
        Ok(c) => c,
        Err(e) => return println!("SKIP {name}: {e:#}"),
    };
    let dir = Path::new("artifacts").join(&cfg.name);
    if !dir.join("manifest.json").exists() {
        if std::env::var("SWITCHHEAD_BENCH_NATIVE").as_deref() == Ok("0") {
            return println!("SKIP {name}: artifacts not built");
        }
        return bench_native(&cfg, name, iters.min(10));
    }
    let engine =
        Engine::load(&dir, Some(&["init", "train_step", "eval_step", "score", "metrics"]))
            .unwrap();
    let mut rng = Pcg::new(1, 1);
    let mut flat = engine.init(1).unwrap();

    let (bufs, _dims): (Vec<_>, Vec<Vec<usize>>) = match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            (
                vec![engine.upload_i32(&tok, &[cfg.batch_size, t1]).unwrap()],
                vec![vec![cfg.batch_size, t1]],
            )
        }
        Task::ListOps => {
            let (tok, lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            (
                vec![
                    engine.upload_i32(&tok, &[cfg.batch_size, cfg.seq_len]).unwrap(),
                    engine.upload_i32(&lab, &[cfg.batch_size]).unwrap(),
                ],
                vec![],
            )
        }
    };
    let refs: Vec<&_> = bufs.iter().collect();

    let mut step = 0;
    let r = time(&format!("{name}/train_step"), 3, iters, || {
        let (next, _) = engine.train_step(&flat, step, &refs, None).unwrap();
        flat = next;
        step += 1;
    });
    println!("{}", r.row());
    let r = time(&format!("{name}/eval_step"), 3, iters, || {
        let _ = engine.eval_step(&flat, &refs).unwrap();
    });
    println!("{}", r.row());
    if cfg.task == Task::Lm && engine.manifest.entries.contains_key("score") {
        let r = time(&format!("{name}/score"), 3, iters, || {
            let _ = engine.score(&flat, &bufs[0]).unwrap();
        });
        println!("{}", r.row());
    }
}

fn half_prompt(cfg: &ModelConfig, rng: &mut Pcg) -> TokenBatch {
    let b = cfg.batch_size;
    let w = (cfg.seq_len / 2).max(1);
    let tok: Vec<i32> = (0..b * w).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    TokenBatch::new(tok, b, w).unwrap()
}

/// Greedy next tokens from the last logits (per batch row).
fn greedy(logits: &switchhead::runtime::Logits, b: usize) -> Vec<i32> {
    (0..b)
        .map(|row| {
            let l = logits.row(row);
            l.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i as i32).unwrap()
        })
        .collect()
}

/// Decode-throughput table: per config, wall-clock and MAC cost of the
/// Session prefill/decode path vs. the legacy full-window recompute —
/// the measurable form of the paper's per-token inference claim.
/// Returns the rows as JSON objects for BENCH_step_latency.json.
fn bench_decode(names: &[&str], iters: usize) -> Vec<Json> {
    let mut table = Table::new(
        "Session decode throughput (native backend, tokens/sec per batch row)",
        &[
            "config",
            "prefill ms",
            "decode ms/tok",
            "int8 ms/tok",
            "recompute ms/tok",
            "speedup",
            "decode tok/s",
            "MACs/tok decode",
            "MACs/tok recompute",
        ],
    );
    let mut json_rows = Vec::new();
    for name in names {
        let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
            Ok(c) => c,
            Err(e) => {
                println!("SKIP {name}: {e:#}");
                continue;
            }
        };
        if cfg.task != Task::Lm {
            continue;
        }
        let engine = NativeEngine::new(&cfg, 42).unwrap();
        let mut rng = Pcg::new(2, 2);
        let b = cfg.batch_size;
        let t = cfg.seq_len;
        let prompt = half_prompt(&cfg, &mut rng);

        // Prefill latency (fresh session each iteration).
        let r_prefill = time(&format!("{name}/prefill"), 1, iters.min(10), || {
            let mut s = engine.open_session(b).unwrap();
            let _ = s.prefill(&prompt).unwrap();
        });

        // Steady-state decode: one long-lived session, time per token,
        // and capture the per-token MAC delta from the session counter.
        let mut session = engine.open_session(b).unwrap();
        let mut logits = session.prefill(&prompt).unwrap();
        let macs_before = session.macs().unwrap().total();
        let mut steps = 0u64;
        let r_decode = time(&format!("{name}/decode"), 2, iters, || {
            let next = greedy(&logits, b);
            logits = session.decode(&next).unwrap();
            steps += 1;
        });
        let decode_macs_tok =
            (session.macs().unwrap().total() - macs_before) / steps as f64 / b as f64;

        // Legacy full-window recompute per token.
        let window: Vec<i32> = (0..b * t).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let window = TokenBatch::new(window, b, t).unwrap();
        let r_full = time(&format!("{name}/recompute"), 1, iters.min(10), || {
            let _ = engine.next_logits(&window).unwrap();
        });
        let full_macs_tok = engine.count_macs().unwrap().total();

        // Int8 variant: the same steady-state decode loop on a
        // quantized engine (int8 expert banks + int8 KV, f32
        // accumulation), plus the weight-memory split it buys.
        let mut qcfg = cfg.clone();
        qcfg.precision = switchhead::config::Precision::Int8;
        let qengine = NativeEngine::new(&qcfg, 42).unwrap();
        let mut qsession = qengine.open_session(b).unwrap();
        let mut qlogits = qsession.prefill(&prompt).unwrap();
        let r_qdecode = time(&format!("{name}/decode int8"), 2, iters, || {
            let next = greedy(&qlogits, b);
            qlogits = qsession.decode(&next).unwrap();
        });
        let weight_bytes_f32 = engine.model.weight_bytes();
        let weight_bytes_int8 = qengine.model.weight_bytes();

        table.push(vec![
            (*name).into(),
            format!("{:.3}", r_prefill.mean_ms),
            format!("{:.3}", r_decode.mean_ms),
            format!("{:.3}", r_qdecode.mean_ms),
            format!("{:.3}", r_full.mean_ms),
            format!("{:.1}x", r_full.mean_ms / r_decode.mean_ms.max(1e-9)),
            format!("{:.0}", 1000.0 / r_decode.mean_ms.max(1e-9)),
            fmt_si(decode_macs_tok),
            fmt_si(full_macs_tok),
        ]);
        json_rows.push(Json::from_pairs(vec![
            ("config", str_(name)),
            ("prefill_ms", num(r_prefill.mean_ms)),
            ("decode_ms_tok", num(r_decode.mean_ms)),
            ("decode_ms_tok_int8", num(r_qdecode.mean_ms)),
            ("recompute_ms_tok", num(r_full.mean_ms)),
            ("decode_tok_s", num(1000.0 / r_decode.mean_ms.max(1e-9))),
            ("macs_tok_decode", num(decode_macs_tok)),
            ("macs_tok_recompute", num(full_macs_tok)),
            ("weight_bytes_f32", num(weight_bytes_f32 as f64)),
            ("weight_bytes_int8", num(weight_bytes_int8 as f64)),
            ("weight_ratio", num(weight_bytes_int8 as f64 / weight_bytes_f32.max(1) as f64)),
        ]));
    }
    table.print();
    json_rows
}

/// Thread-scaling table: session prefill / steady-state decode at each
/// thread count, same seeds — the wall-clock form of the MoE dispatch
/// and blocked-kernel win. Returns JSON rows.
fn bench_thread_scaling(names: &[&str], threads_list: &[usize], iters: usize) -> Vec<Json> {
    let mut table = Table::new(
        "Thread scaling (kernels::set_threads; identical bits at every count)",
        &["config", "threads", "prefill ms", "decode ms/tok", "prefill speedup vs 1T"],
    );
    let mut json_rows = Vec::new();
    for name in names {
        let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
            Ok(c) => c,
            Err(e) => {
                println!("SKIP {name}: {e:#}");
                continue;
            }
        };
        if cfg.task != Task::Lm {
            continue;
        }
        let engine = NativeEngine::new(&cfg, 42).unwrap();
        let b = cfg.batch_size;
        let mut base_prefill = f64::NAN;
        for &threads in threads_list {
            kernels::set_threads(threads);
            let mut rng = Pcg::new(2, 2);
            let prompt = half_prompt(&cfg, &mut rng);
            let r_prefill = time(&format!("{name}/{threads}T prefill"), 1, iters.min(10), || {
                let mut s = engine.open_session(b).unwrap();
                let _ = s.prefill(&prompt).unwrap();
            });
            let mut session = engine.open_session(b).unwrap();
            let mut logits = session.prefill(&prompt).unwrap();
            let r_decode = time(&format!("{name}/{threads}T decode"), 2, iters, || {
                let next = greedy(&logits, b);
                logits = session.decode(&next).unwrap();
            });
            if threads == threads_list[0] {
                base_prefill = r_prefill.mean_ms;
            }
            let speedup = base_prefill / r_prefill.mean_ms.max(1e-9);
            table.push(vec![
                (*name).into(),
                format!("{threads}"),
                format!("{:.3}", r_prefill.mean_ms),
                format!("{:.3}", r_decode.mean_ms),
                format!("{speedup:.2}x"),
            ]);
            json_rows.push(Json::from_pairs(vec![
                ("config", str_(name)),
                ("threads", num(threads as f64)),
                ("prefill_ms", num(r_prefill.mean_ms)),
                ("decode_ms_tok", num(r_decode.mean_ms)),
                ("prefill_speedup_vs_1t", num(speedup)),
            ]));
        }
    }
    table.print();
    json_rows
}

/// Kernel-level microbench: dense blocked matmul vs expert-grouped MoE
/// dispatch, GFLOP/s per thread count — the expert-grouping win in
/// isolation from the model. Returns JSON rows.
fn bench_kernels(threads_list: &[usize], iters: usize) -> Vec<Json> {
    // Shapes sized like a mid-size token batch so the grouped dispatch
    // has real buckets to exploit: n tokens of width d projected to m.
    let (n, d, m) = (512usize, 256usize, 256usize);
    let (ne, k) = (4usize, 2usize);
    let mut rng = Pcg::new(3, 3);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..d * m).map(|_| rng.normal() as f32).collect();
    let experts: Vec<Vec<f32>> =
        (0..ne).map(|_| (0..d * m).map(|_| rng.normal() as f32).collect()).collect();
    let idx: Vec<usize> = (0..n * k).map(|_| rng.below(ne)).collect();
    let gate: Vec<f32> = (0..n * k).map(|_| (rng.normal() as f32).abs() + 0.1).collect();

    let mut table = Table::new(
        "Kernel microbench (dense blocked matmul vs expert-grouped MoE dispatch)",
        &["kernel", "threads", "GFLOP/s", "ms/call", "pool busy"],
    );
    let mut json_rows = Vec::new();
    let dense_flops = 2.0 * (n * d * m) as f64;
    let moe_flops = 2.0 * (n * k * d * m) as f64;
    let mut out = vec![0f32; n * m];
    // Worker occupancy per timed region: busy_ns over the pool's
    // wall-clock capacity. The `time` helper runs `warmup + iters`
    // calls, all of which the busy counter covers.
    let busy_frac = |mean_ms: f64, calls: usize, threads: usize| {
        let wall_s = mean_ms / 1e3 * calls as f64;
        kernels::pool::busy_ns() as f64 * 1e-9 / (wall_s * threads as f64).max(1e-12)
    };
    for &threads in threads_list {
        kernels::set_threads(threads);
        let calls = 2 + iters.min(20);
        kernels::pool::reset_busy_ns();
        kernels::pool::set_busy_timing(true);
        let r = time(&format!("kernel/dense {threads}T"), 2, iters.min(20), || {
            kernels::matmul_into(&mut out, &x, &w, n, d, m);
        });
        kernels::pool::set_busy_timing(false);
        let dense_busy = busy_frac(r.mean_ms, calls, threads);
        let gflops = dense_flops / (r.mean_ms / 1000.0) / 1e9;
        table.push(vec![
            "dense matmul".into(),
            format!("{threads}"),
            format!("{gflops:.2}"),
            format!("{:.3}", r.mean_ms),
            format!("{:.0}%", 100.0 * dense_busy),
        ]);
        json_rows.push(Json::from_pairs(vec![
            ("kernel", str_("dense_matmul")),
            ("threads", num(threads as f64)),
            ("gflops", num(gflops)),
            ("ms_per_call", num(r.mean_ms)),
            ("pool_busy_frac", num(dense_busy)),
        ]));
        kernels::pool::reset_busy_ns();
        kernels::pool::set_busy_timing(true);
        let r = time(&format!("kernel/moe {threads}T"), 2, iters.min(20), || {
            kernels::moe_matmul_into(&mut out, &x, &experts, d, m, &idx, &gate, k);
        });
        kernels::pool::set_busy_timing(false);
        let moe_busy = busy_frac(r.mean_ms, calls, threads);
        let gflops = moe_flops / (r.mean_ms / 1000.0) / 1e9;
        table.push(vec![
            "moe grouped".into(),
            format!("{threads}"),
            format!("{gflops:.2}"),
            format!("{:.3}", r.mean_ms),
            format!("{:.0}%", 100.0 * moe_busy),
        ]);
        json_rows.push(Json::from_pairs(vec![
            ("kernel", str_("moe_grouped_matmul")),
            ("threads", num(threads as f64)),
            ("gflops", num(gflops)),
            ("ms_per_call", num(r.mean_ms)),
            ("pool_busy_frac", num(moe_busy)),
        ]));
    }
    table.print();
    json_rows
}

fn main() {
    let smoke = std::env::var("SWITCHHEAD_BENCH_SMOKE").as_deref() == Ok("1");
    let iters: usize = std::env::var("SWITCHHEAD_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 2 } else { 30 });
    let threads_list: &[usize] = if smoke { &[1] } else { &[1, 2, 4] };
    // Capture before any set_threads so the JSON records the
    // PALLAS_THREADS / available_parallelism default of this host.
    let default_threads = kernels::threads();

    for name in ["tiny-dense", "tiny-sh", "tiny-moa", "tiny-switchall"] {
        bench_config(name, iters);
    }
    let decode = bench_decode(&["tiny-dense", "tiny-sh", "tiny-rope-sh", "tiny-switchall"], iters);
    let scaling = bench_thread_scaling(&["tiny-sh", "tiny-dense"], threads_list, iters);
    let kern = bench_kernels(threads_list, iters);

    let out = Json::from_pairs(vec![
        ("bench", str_("step_latency")),
        ("iters", num(iters as f64)),
        ("smoke", Json::Bool(smoke)),
        ("threads_default", num(default_threads as f64)),
        ("decode", Json::Arr(decode)),
        ("thread_scaling", Json::Arr(scaling)),
        ("kernels", Json::Arr(kern)),
    ]);
    // Smoke runs land under target/ (gitignored) so `make check` never
    // dirties the tree or clobbers a real `make bench` trajectory file.
    let path =
        if smoke { "target/BENCH_step_latency.smoke.json" } else { "BENCH_step_latency.json" };
    match std::fs::write(path, out.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN: could not write {path}: {e}"),
    }
}
