//! Micro-benchmark: per-entry PJRT execution latency (train_step /
//! eval_step / score) for the parameter-matched tiny family. This is the
//! L3 §Perf instrument — it separates coordinator overhead (upload +
//! readback) from device execute time. See EXPERIMENTS.md §Perf.
use std::path::Path;

use switchhead::bench::time;
use switchhead::config::{ModelConfig, Task};
use switchhead::runtime::Engine;
use switchhead::util::rng::Pcg;

fn bench_config(name: &str, iters: usize) {
    let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
        Ok(c) => c,
        Err(e) => return println!("SKIP {name}: {e:#}"),
    };
    let dir = Path::new("artifacts").join(&cfg.name);
    if !dir.join("manifest.json").exists() {
        return println!("SKIP {name}: artifacts not built");
    }
    let engine =
        Engine::load(&dir, Some(&["init", "train_step", "eval_step", "score", "metrics"]))
            .unwrap();
    let mut rng = Pcg::new(1, 1);
    let mut flat = engine.init(1).unwrap();

    let (bufs, _dims): (Vec<_>, Vec<Vec<usize>>) = match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            (
                vec![engine.upload_i32(&tok, &[cfg.batch_size, t1]).unwrap()],
                vec![vec![cfg.batch_size, t1]],
            )
        }
        Task::ListOps => {
            let (tok, lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            (
                vec![
                    engine.upload_i32(&tok, &[cfg.batch_size, cfg.seq_len]).unwrap(),
                    engine.upload_i32(&lab, &[cfg.batch_size]).unwrap(),
                ],
                vec![],
            )
        }
    };
    let refs: Vec<&_> = bufs.iter().collect();

    let mut step = 0;
    let r = time(&format!("{name}/train_step"), 3, iters, || {
        let (next, _) = engine.train_step(&flat, step, &refs, None).unwrap();
        flat = next;
        step += 1;
    });
    println!("{}", r.row());
    let r = time(&format!("{name}/eval_step"), 3, iters, || {
        let _ = engine.eval_step(&flat, &refs).unwrap();
    });
    println!("{}", r.row());
    if cfg.task == Task::Lm && engine.manifest.entries.contains_key("score") {
        let r = time(&format!("{name}/score"), 3, iters, || {
            let _ = engine.score(&flat, &bufs[0]).unwrap();
        });
        println!("{}", r.row());
    }
}

fn main() {
    let iters: usize = std::env::var("SWITCHHEAD_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    for name in ["tiny-dense", "tiny-sh", "tiny-moa", "tiny-switchall"] {
        bench_config(name, iters);
    }
}
