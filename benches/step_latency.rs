//! Micro-benchmark: per-entry execution latency (train_step / eval_step
//! / score) for the parameter-matched tiny family. This is the L3 §Perf
//! instrument — it separates coordinator overhead (upload + readback)
//! from device execute time. See EXPERIMENTS.md §Perf.
//!
//! Smoke mode: when a config's PJRT artifacts are absent (clean
//! checkout, no Python), the native backend is timed instead —
//! `score` and `next_logits` on host buffers — so `make smoke` always
//! produces latency rows. Set SWITCHHEAD_BENCH_NATIVE=0 to disable the
//! fallback.
use std::path::Path;

use switchhead::bench::time;
use switchhead::config::{ModelConfig, Task};
use switchhead::model::NativeEngine;
use switchhead::runtime::Engine;
use switchhead::util::rng::Pcg;

/// Native-backend smoke rows (artifact-free).
fn bench_native(cfg: &ModelConfig, name: &str, iters: usize) {
    let engine = match NativeEngine::new(cfg, 42) {
        Ok(e) => e,
        Err(e) => return println!("SKIP {name} (native): {e:#}"),
    };
    let mut rng = Pcg::new(1, 1);
    match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            let r = time(&format!("{name}/native score"), 1, iters, || {
                let _ = engine.score(&tok, &[cfg.batch_size, t1]).unwrap();
            });
            println!("{}", r.row());
            let tok2: Vec<i32> = tok[..cfg.batch_size * cfg.seq_len].to_vec();
            let r = time(&format!("{name}/native next_logits"), 1, iters, || {
                let _ = engine.next_logits(&tok2, &[cfg.batch_size, cfg.seq_len]).unwrap();
            });
            println!("{}", r.row());
        }
        Task::ListOps => {
            let (tok, _lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            let r = time(&format!("{name}/native class_logits"), 1, iters, || {
                let _ = engine.class_logits(&tok, &[cfg.batch_size, cfg.seq_len]).unwrap();
            });
            println!("{}", r.row());
        }
    }
}

fn bench_config(name: &str, iters: usize) {
    let cfg = match ModelConfig::load(&format!("configs/{name}.json")) {
        Ok(c) => c,
        Err(e) => return println!("SKIP {name}: {e:#}"),
    };
    let dir = Path::new("artifacts").join(&cfg.name);
    if !dir.join("manifest.json").exists() {
        if std::env::var("SWITCHHEAD_BENCH_NATIVE").as_deref() == Ok("0") {
            return println!("SKIP {name}: artifacts not built");
        }
        return bench_native(&cfg, name, iters.min(10));
    }
    let engine =
        Engine::load(&dir, Some(&["init", "train_step", "eval_step", "score", "metrics"]))
            .unwrap();
    let mut rng = Pcg::new(1, 1);
    let mut flat = engine.init(1).unwrap();

    let (bufs, _dims): (Vec<_>, Vec<Vec<usize>>) = match cfg.task {
        Task::Lm => {
            let t1 = cfg.seq_len + 1;
            let tok: Vec<i32> =
                (0..cfg.batch_size * t1).map(|_| rng.below(cfg.vocab_size) as i32).collect();
            (
                vec![engine.upload_i32(&tok, &[cfg.batch_size, t1]).unwrap()],
                vec![vec![cfg.batch_size, t1]],
            )
        }
        Task::ListOps => {
            let (tok, lab) =
                switchhead::data::listops::gen_batch(&mut rng, cfg.batch_size, cfg.seq_len);
            (
                vec![
                    engine.upload_i32(&tok, &[cfg.batch_size, cfg.seq_len]).unwrap(),
                    engine.upload_i32(&lab, &[cfg.batch_size]).unwrap(),
                ],
                vec![],
            )
        }
    };
    let refs: Vec<&_> = bufs.iter().collect();

    let mut step = 0;
    let r = time(&format!("{name}/train_step"), 3, iters, || {
        let (next, _) = engine.train_step(&flat, step, &refs, None).unwrap();
        flat = next;
        step += 1;
    });
    println!("{}", r.row());
    let r = time(&format!("{name}/eval_step"), 3, iters, || {
        let _ = engine.eval_step(&flat, &refs).unwrap();
    });
    println!("{}", r.row());
    if cfg.task == Task::Lm && engine.manifest.entries.contains_key("score") {
        let r = time(&format!("{name}/score"), 3, iters, || {
            let _ = engine.score(&flat, &bufs[0]).unwrap();
        });
        println!("{}", r.row());
    }
}

fn main() {
    let iters: usize = std::env::var("SWITCHHEAD_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    for name in ["tiny-dense", "tiny-sh", "tiny-moa", "tiny-switchall"] {
        bench_config(name, iters);
    }
}
